"""Elastic restart: save on one mesh shape, restore on another."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code, devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_save_8dev_restore_4dev(tmp_path):
    ckpt = str(tmp_path / "ck")
    _run(f"""
        import jax
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs import get_config
        from repro.distributed.sharding import param_shardings, set_mesh_rules
        from repro.launch.elastic import best_mesh_for
        from repro.models.registry import get_model

        cfg = get_config("qwen2-7b", smoke=True)
        model = get_model(cfg)
        mesh = best_mesh_for(8, prefer_model=4)
        set_mesh_rules(mesh, fsdp=False)
        params = model.init(jax.random.key(0), cfg)
        params = jax.device_put(params, param_shardings(params, mesh))
        CheckpointManager({ckpt!r}).save(7, {{"params": params}},
                                         blocking=True)
        print("SAVED", dict(mesh.shape))
    """, devices=8)
    out = _run(f"""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.launch.elastic import resume_elastic
        from repro.models.registry import get_model
        from repro.data.pipeline import smoke_batch

        cfg, batch = smoke_batch("qwen2-7b", "train_4k")
        model = get_model(cfg)
        mesh, state, step = resume_elastic({ckpt!r}, model, cfg,
                                           prefer_model=2)
        assert step == 7, step
        with mesh:
            loss, _ = jax.jit(lambda p, b: model.loss(p, b, cfg))(
                state["params"], batch)
        assert np.isfinite(float(loss))
        print("RESTORED", dict(mesh.shape), float(loss))
    """, devices=4)
    assert "RESTORED" in out
