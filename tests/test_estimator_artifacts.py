"""Tests for the versioned, jit-served estimator layer:

  * pickle-free artifact round-trips (all five model names, both chips);
  * load-time rejection of tampered schemas/arrays and legacy pickles;
  * vectorized stacked-descent prediction == per-tree-loop parity;
  * chip-derived anchor power (no hardcoded 130 W);
  * tuner: batched tune_many, fingerprint-keyed winner cache, cached
    BASELINE fallback, and rank latency vs the pre-refactor loop path.
"""

import json
import pickle
import time

import numpy as np
import pytest

from repro.core.autotuner import BASELINE, GemmAutotuner
from repro.core.chips import get_chip
from repro.core.features import features_matrix, table_from_configs
from repro.core.hwsim import TpuGemmSimulator
from repro.core.predictor import (
    ARTIFACT_SCHEMA_VERSION,
    MODEL_NAMES,
    ArtifactError,
    PerfPredictor,
)
from repro.core.profiler import collect_dataset, sweep_configs

CHIPS = ("tpu_v5e", "rtx4070")


@pytest.fixture(scope="module")
def tables():
    return {chip: collect_dataset(n_configs=800, seed=0, chip=chip)
            for chip in CHIPS}


@pytest.fixture(scope="module")
def rf_pred(tables):
    return PerfPredictor(model="rf", residual=True, fast=True,
                         chip="tpu_v5e").fit(tables["tpu_v5e"])


def _tamper(path, mutate):
    """Rewrite an artifact after applying `mutate(meta, arrays)`."""
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(str(arrays.pop("__meta__")[()]))
    mutate(meta, arrays)
    with open(path, "wb") as f:
        np.savez_compressed(f, __meta__=np.array(json.dumps(meta)), **arrays)


class TestArtifactRoundTrip:
    @pytest.mark.parametrize("chip", CHIPS)
    @pytest.mark.parametrize("model", MODEL_NAMES)
    def test_save_load_predict_parity(self, model, chip, tables, tmp_path):
        pred = PerfPredictor(model=model, residual=True, fast=True,
                             chip=chip).fit(tables[chip])
        path = str(tmp_path / f"{model}_{chip}.npz")
        pred.save(path)
        back = PerfPredictor.load(path)
        assert back.model_name == model
        assert back.chip_name == chip
        assert back.nominal_power_w == get_chip(chip).nominal_power_w
        assert back.fingerprint() == pred.fingerprint()
        np.testing.assert_allclose(back.predict_matrix(tables[chip]),
                                   pred.predict_matrix(tables[chip]),
                                   rtol=1e-12)

    def test_no_pickle_in_predictor_module(self):
        import repro.core.predictor as mod

        src = open(mod.__file__).read()
        assert "import pickle" not in src
        assert "pickle.load" not in src
        assert "pickle.dump" not in src

    def test_artifact_loads_without_pickle_support(self, rf_pred, tmp_path):
        """np.load(allow_pickle=False) must be sufficient: no object
        arrays anywhere in the artifact."""
        path = str(tmp_path / "a.npz")
        rf_pred.save(path)
        with np.load(path, allow_pickle=False) as z:
            for k in z.files:
                assert z[k].dtype != object, k


class TestArtifactValidation:
    def test_tampered_feature_schema_rejected(self, rf_pred, tmp_path):
        path = str(tmp_path / "a.npz")
        rf_pred.save(path)

        def drop_feature(meta, arrays):
            meta["feature_names"] = meta["feature_names"][:-1]

        _tamper(path, drop_feature)
        with pytest.raises(ArtifactError, match="feature schema"):
            PerfPredictor.load(path)

    def test_tampered_arrays_rejected(self, rf_pred, tmp_path):
        path = str(tmp_path / "a.npz")
        rf_pred.save(path)

        def poison_threshold(meta, arrays):
            key = "model/threshold"
            arrays[key] = arrays[key] * 1.5

        _tamper(path, poison_threshold)
        with pytest.raises(ArtifactError, match="fingerprint"):
            PerfPredictor.load(path)

    def test_wrong_schema_version_rejected(self, rf_pred, tmp_path):
        path = str(tmp_path / "a.npz")
        rf_pred.save(path)
        _tamper(path, lambda meta, arrays: meta.update(
            schema_version=ARTIFACT_SCHEMA_VERSION + 1,
        ))
        with pytest.raises(ArtifactError, match="schema version"):
            PerfPredictor.load(path)

    def test_old_schema_without_upgrader_rejected(self, rf_pred, tmp_path):
        path = str(tmp_path / "a.npz")
        rf_pred.save(path)
        _tamper(path, lambda meta, arrays: meta.update(schema_version=0))
        with pytest.raises(ArtifactError, match="no upgrade path"):
            PerfPredictor.load(path)

    def test_old_schema_loads_through_registered_upgrader(
            self, rf_pred, tmp_path, tables):
        """The v(N-1) -> v(N) migration story: an artifact one schema
        behind loads through its registered upgrader — including one that
        rewrites arrays, provided it restamps the fingerprint."""
        from repro.core.predictor import (
            _SCHEMA_UPGRADERS,
            artifact_fingerprint,
        )

        path = str(tmp_path / "a.npz")
        rf_pred.save(path)
        # simulate a legacy artifact: old version tag + a renamed array
        # key the upgrader must translate back
        _tamper(path, lambda meta, arrays: (
            meta.update(schema_version=0),
            arrays.update(legacy_marker=np.zeros(1))))

        def upgrade(meta, state):
            state = dict(state)
            state.pop("legacy_marker")
            meta = dict(meta, schema_version=1,
                        fingerprint=artifact_fingerprint(meta, state))
            return meta, state

        _SCHEMA_UPGRADERS[0] = upgrade
        try:
            loaded = PerfPredictor.load(path)
        finally:
            del _SCHEMA_UPGRADERS[0]
        te = tables["tpu_v5e"]
        np.testing.assert_allclose(loaded.predict_matrix(te),
                                   rf_pred.predict_matrix(te), rtol=1e-12)

    def test_legacy_pickle_rejected(self, rf_pred, tmp_path):
        path = str(tmp_path / "legacy.pkl")
        with open(path, "wb") as f:
            pickle.dump({"anything": 1}, f)
        with pytest.raises(ArtifactError):
            PerfPredictor.load(path)

    def test_build_default_predictor_retrains_over_bad_artifact(
            self, tmp_path):
        from repro.core.autotuner import build_default_predictor

        art = str(tmp_path)
        bad = tmp_path / "perf_predictor_tpu_v5e.npz"
        bad.write_bytes(b"not an artifact")
        pred = build_default_predictor(art, n_train=300, chip="tpu_v5e")
        assert pred.chip_name == "tpu_v5e"
        # the retrain overwrote the corrupt file with a loadable artifact
        assert PerfPredictor.load(str(bad)).fingerprint() == pred.fingerprint()


class TestVectorizedPredict:
    def test_forest_stacked_equals_per_tree_loop(self, rf_pred, tables):
        X = rf_pred.scaler.transform(
            np.stack([tables["tpu_v5e"][k] for k in rf_pred.feature_names],
                     axis=1))
        np.testing.assert_allclose(rf_pred.model.predict(X),
                                   rf_pred.model.predict_per_tree_loop(X),
                                   rtol=1e-12)

    def test_gbdt_stacked_equals_per_tree_loop(self, tables):
        pred = PerfPredictor(model="gbdt", residual=True, fast=True,
                             chip="tpu_v5e").fit(tables["tpu_v5e"])
        X = pred.scaler.transform(
            np.stack([tables["tpu_v5e"][k] for k in pred.feature_names],
                     axis=1))
        np.testing.assert_allclose(pred.model.predict(X),
                                   pred.model.predict_per_tree_loop(X),
                                   rtol=1e-10)

    def test_x64_jit_scorer_matches_numpy(self, rf_pred, tables):
        table = {k: v[:200] for k, v in tables["tpu_v5e"].items()}
        X = np.stack([table[k] for k in rf_pred.feature_names], axis=1)
        got = np.asarray(rf_pred.jax_predictor(x64=True)(X))
        want = rf_pred.predict_matrix(table)
        np.testing.assert_allclose(got, want, rtol=1e-9)

    def test_jax_predictor_cached_per_precision(self, rf_pred):
        assert rf_pred.jax_predictor(x64=True) is rf_pred.jax_predictor(x64=True)
        assert rf_pred.jax_predictor() is rf_pred.jax_predictor()
        assert rf_pred.jax_predictor() is not rf_pred.jax_predictor(x64=True)


class TestChipAnchors:
    def test_nominal_power_follows_chip(self):
        assert PerfPredictor(chip="tpu_v5e").nominal_power_w == 130.0
        assert PerfPredictor(chip="rtx4070").nominal_power_w == 142.5
        assert PerfPredictor().nominal_power_w == 130.0  # default chip

    def test_energy_anchor_uses_chip_power(self, tables):
        table = tables["rtx4070"]
        p_ada = PerfPredictor(chip="rtx4070")
        p_tpu = PerfPredictor(chip="tpu_v5e")
        a_ada = p_ada._anchors(table)["energy_j"]
        a_tpu = p_tpu._anchors(table)["energy_j"]
        np.testing.assert_allclose(a_ada / a_tpu, 142.5 / 130.0)


class TestTunerServing:
    @pytest.fixture(scope="class")
    def tuner(self, rf_pred):
        return GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3))

    def test_tune_many_matches_cached_best_config(self, tuner):
        shapes = [(1024, 1024, 1024), (4096, 4096, 1024), (16, 2048, 2048)]
        fleet = tuner.tune_many(shapes)
        assert len(fleet) == len(shapes)
        for s, cfg in zip(shapes, fleet):
            assert tuner.best_config(*s) == cfg

    def test_empty_candidates_fallback_cached(self, rf_pred):
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3))
        calls = []
        orig = tuner.candidate_configs
        tuner.candidate_configs = lambda *a, **k: (calls.append(a), [])[1]
        assert tuner.best_config(3, 3, 3) == BASELINE
        assert tuner.best_config(3, 3, 3) == BASELINE
        assert len(calls) == 1, "BASELINE fallback must be cached"
        tuner.candidate_configs = orig

    def test_winner_cache_keyed_by_artifact_fingerprint(
            self, rf_pred, tables, tmp_path):
        cache = str(tmp_path / "cache.json")
        t1 = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                           cache_path=cache)
        t1.best_config(2048, 2048, 2048)
        # same artifact -> winners survive
        t2 = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                           cache_path=cache)
        assert t2._cache
        # retrained artifact -> stale winners discarded
        retrained = PerfPredictor(model="rf", residual=True, fast=True,
                                  chip="tpu_v5e",
                                  random_state=9).fit(tables["tpu_v5e"])
        assert retrained.fingerprint() != rf_pred.fingerprint()
        t3 = GemmAutotuner(retrained, TpuGemmSimulator(seed=3),
                           cache_path=cache)
        assert t3._cache == {}

    def test_trace_dtype_strings_canonicalized(self, tuner):
        """ops.matmul keys tuning by str(a.dtype) ("bfloat16"); the tuner
        must resolve that to the substrate's dtype grid, not crash."""
        cfg = tuner.best_config(512, 512, 512, dtype="bfloat16")
        assert cfg == tuner.best_config(512, 512, 512, dtype="bf16")

    def test_rank_parity_both_scorers(self, rf_pred):
        cfgs = sweep_configs(n_configs=512, seed=1)
        ref = rf_pred.predict_matrix_reference(table_from_configs(cfgs))
        for scorer in ("numpy", "jit"):
            tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                                  scorer=scorer)
            X = features_matrix(cfgs, chip=tuner.chip)
            got = tuner._predict_features(X)
            rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-12)
            assert rel.max() < 1e-4, (scorer, rel.max())

    @pytest.mark.slow
    def test_rank_512_beats_per_tree_loop(self, rf_pred):
        """The refactored rank path (cached candidate features + stacked
        descent) vs the pre-refactor path (per-call table build + per-tree
        loop). Quiet-machine ratio is ~5-6x (see benchmarks/rank_smoke.py
        and bench_autotune); assert 4x best-of-interleaved so CI noise
        can't flake the suite."""
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3))
        cfgs = sweep_configs(n_configs=512, seed=1)
        X = features_matrix(cfgs, chip=tuner.chip)

        def rank_new():
            return tuner.rank(cfgs, features=X)

        def rank_reference():
            t = table_from_configs(cfgs, chip=tuner.chip)
            # stable, matching rank()'s deterministic tie-break
            return np.argsort(rf_pred.predict_matrix_reference(t)[:, 0],
                              kind="stable")

        rank_new(), rank_reference()
        t_new, t_ref = [], []
        for _ in range(20):  # interleaved so load spikes hit both paths
            t0 = time.perf_counter()
            rank_new()
            t_new.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            rank_reference()
            t_ref.append(time.perf_counter() - t0)
        assert min(t_ref) > 4.0 * min(t_new), (min(t_ref), min(t_new))
        np.testing.assert_array_equal(rank_new(), rank_reference())


class TestWinnerCacheLRU:
    def _shape(self, i):
        return (128 * (i + 1), 256, 512)

    def test_memory_eviction_lru_order(self, rf_pred, tmp_path):
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                              winner_cache_size=4)
        for i in range(6):
            tuner.best_config(*self._shape(i))
        assert len(tuner._cache) == 4
        # oldest two evicted, newest four retained
        keys = list(tuner._cache)
        assert keys == [tuner._key(*self._shape(i), "bf16", "runtime")
                        for i in range(2, 6)]

    def test_hit_refreshes_recency(self, rf_pred):
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                              winner_cache_size=2)
        a, b, c = self._shape(0), self._shape(1), self._shape(2)
        tuner.best_config(*a)
        tuner.best_config(*b)
        tuner.best_config(*a)      # refresh a: b becomes the LRU entry
        tuner.best_config(*c)      # evicts b, not a
        keys = set(tuner._cache)
        assert tuner._key(*a, "bf16", "runtime") in keys
        assert tuner._key(*b, "bf16", "runtime") not in keys

    def test_sidecar_bounded_and_reloadable(self, rf_pred, tmp_path):
        cache = str(tmp_path / "cache.json")
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                              cache_path=cache, winner_cache_size=3)
        for i in range(6):
            tuner.best_config(*self._shape(i))
        with open(cache) as f:
            payload = json.load(f)
        assert len(payload["entries"]) == 3  # sidecar stays bounded

        # reload: entries survive in order, and a tighter bound trims the
        # oldest on load
        t2 = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                           cache_path=cache, winner_cache_size=3)
        assert list(t2._cache) == list(payload["entries"])
        t3 = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                           cache_path=cache, winner_cache_size=2)
        assert list(t3._cache) == list(payload["entries"])[-2:]


class TestMeasureFn:
    """`tune_many(measure_fn=...)`: the wall-clock verification hook."""

    def test_fake_clock_overrides_simulator(self, rf_pred):
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3))
        tuner.sim.measure_batch = lambda *a, **k: pytest.fail(
            "simulator must not measure when measure_fn is given")
        calls = []

        def fake_clock(cfgs):
            calls.append(list(cfgs))
            n = len(cfgs)
            # the "clock" says the LAST verified candidate is fastest
            rt = np.arange(n, 0, -1, dtype=np.float64)
            return {"runtime_ms": rt, "power_w": np.full(n, 100.0),
                    "energy_j": rt * 0.1}

        best = tuner.best_config(1024, 1024, 1024, measure_fn=fake_clock)
        assert len(calls) == 1
        assert 1 <= len(calls[0]) <= tuner.verify_top_k
        w = calls[0][-1]
        assert best.as_tuple() == (w.block_m, w.block_n, w.block_k)

    def test_fake_clock_winner_cached(self, rf_pred):
        tuner = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3))
        seen = []

        def fake_clock(cfgs):
            seen.append(len(cfgs))
            n = len(cfgs)
            rt = np.arange(1, n + 1, dtype=np.float64)
            return {"runtime_ms": rt, "power_w": np.full(n, 90.0),
                    "energy_j": rt}

        a = tuner.best_config(512, 512, 512, measure_fn=fake_clock)
        b = tuner.best_config(512, 512, 512, measure_fn=fake_clock)
        assert a == b
        assert len(seen) == 1, "cached winner must not re-measure"


class TestWarmGemmCache:
    def test_warm_primes_trace_time_cache(self, rf_pred):
        from repro.core import autotuner as at
        from repro.kernels import ops

        at.set_tuner(GemmAutotuner(rf_pred, TpuGemmSimulator(seed=0)))
        ops._tuned_config.cache_clear()
        try:
            shapes = [(256, 512, 1024), (128, 256, 512)]
            out = ops.warm_gemm_cache(shapes, dtype="bfloat16")
            assert set(out) == set(shapes)
            for (m, n, k), cfg in out.items():
                assert ops._tuned_config(
                    m, n, k, "bfloat16", "runtime", "tpu_v5e") == cfg
        finally:
            at.set_tuner(None)
            ops._tuned_config.cache_clear()
