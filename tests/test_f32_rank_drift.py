"""f32 in-graph ranking drift vs scoped-x64 on the shipped artifacts.

ROADMAP follow-up (PR 3): quantify whether the approximate f32
`rank_in_graph` mode picks different winners than the bit-parity x64
default. On every committed golden artifact, over the serving GEMM fleet
(decode + prefill + chunked-admission grid), the measured drift is zero —
pinned here so a scorer/feature change that *introduces* f32 drift fails
loudly and the serve-f32 decision (README) gets revisited.
"""

from __future__ import annotations

import os

import pytest

from gen_golden_fixtures import FIXTURE_DIR, GOLDEN_FAMILIES


def _keys(cfgs):
    return [(c.block_m, c.block_n, c.block_k) for c in cfgs]


@pytest.fixture(scope="module")
def fleet():
    from repro.kernels import ops
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="drift", kind="dense", n_layers=2, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=1024, vocab=4096)
    shapes = ops.serving_gemm_fleet(cfg, max_batch=8, max_len=512,
                                    chunk_tokens=64, lane_width=16)
    assert len(shapes) >= 32          # a real fleet, not a toy list
    return shapes


@pytest.mark.parametrize("family", GOLDEN_FAMILIES)
def test_f32_winners_match_x64_on_golden_artifacts(family, fleet):
    from repro.core.autotuner import GemmAutotuner
    from repro.core.hwsim import TpuGemmSimulator
    from repro.core.predictor import PerfPredictor

    pred = PerfPredictor.load(
        os.path.join(FIXTURE_DIR, f"golden_{family}.npz"))
    tuner = GemmAutotuner(pred, TpuGemmSimulator(seed=0), scorer="jit")
    tops64, _ = tuner.rank_in_graph(fleet, top_k=3, x64=True)
    tops32, _ = tuner.rank_in_graph(fleet, top_k=3, x64=False)
    mismatches = [s for s, a, b in zip(fleet, tops64, tops32)
                  if _keys(a) != _keys(b)]
    assert mismatches == [], (
        f"{family}: f32 in-graph ranking drifted from x64 on "
        f"{len(mismatches)}/{len(fleet)} fleet shapes — revisit the "
        f"serve-f32 decision in README")
