"""Chaos property suite: fault injection, migration/replay recovery,
and overload admission control on the serving fleet.

The invariants, under ANY seeded fault schedule:

* **Exactly-once**: every submitted request reaches exactly one
  terminal disposition (finished, or shed/lost with a logged status);
  no request is served twice and none is dropped silently.
* **Stream integrity**: client-visible token streams are append-only
  across failures — a replayed request's forced prefix reproduces what
  already streamed, and a migrated decode-state row continues
  bit-identically — so final streams match a no-fault single-engine
  reference exactly (the engine's bit-parity contract survives chaos).
* **Ledger conservation**: fleet energy still sums from the per-engine
  ledgers, with the failed attempt's unusable spend charged to the
  failed member (`lost_energy_j`), never double-counted and never
  vanishing.
* **Degraded continuity**: predictor-artifact corruption downgrades
  tuning to BASELINE configs (flagged in `report()`), and page-pool
  pressure sheds the shared-prefix registry — both change costs and
  latency only, never tokens.

Runs under hypothesis when available, with a deterministic seeded
fallback — the same two-tier pattern as `tests/test_fleet_scheduler.py`,
whose helpers this suite mirrors.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.autotuner import BASELINE
from repro.core.predictor import ArtifactError
from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultEvent, FaultPlan, retry_backoff_s
from repro.serving.paging import PageAllocator
from repro.serving.scheduler import FleetScheduler, SLAClass
from repro.train.ft import StragglerConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="chaos-test", kind="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        param_dtype="float32", activation_dtype="float32", remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


_SERVED_CACHE: dict = {}


def _get_served():
    """Memoized (cfg, model, params) triple shared by every test (and
    by the hypothesis tier, which bypasses fixture injection)."""
    if "served" not in _SERVED_CACHE:
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        _SERVED_CACHE["served"] = (cfg, model, params)
    return _SERVED_CACHE["served"]


@pytest.fixture(scope="module")
def served():
    return _get_served()


def make_engine(served, chip: str = "tpu_v5e", **kw) -> ServingEngine:
    cfg, model, params = served
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("chunk_tokens", 16)
    kw.setdefault("seed", 0)
    return ServingEngine(model, params, cfg, chip=chip, **kw)


def make_fleet(served, slo: float | None = 0.5,
               **sched_kw) -> FleetScheduler:
    """Two-member heterogeneous fleet (TPU v5e + RTX 4070) sharing
    params and sampling seed — the members are `state_compatible`, so
    migration is available whenever checkpointed state survives."""
    engines = {"v5e": make_engine(served, "tpu_v5e"),
               "ada": make_engine(served, "rtx4070")}
    if slo is None:
        return FleetScheduler(engines, **sched_kw)
    sched_kw.setdefault("default_sla", "interactive")
    sla = sched_kw.pop("sla", {"interactive": SLAClass("interactive", slo)})
    return FleetScheduler(engines, sla=sla, **sched_kw)


def workload(seed: int, n: int, lo: int = 3, hi: int = 40,
             max_budget: int = 8) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(0, 256, int(rng.integers(lo, hi))
                                    ).astype(np.int32),
                max_new_tokens=int(rng.integers(1, max_budget + 1)))
        for i in range(n)]


_REF_CACHE: dict = {}


def _reference(served, seed: int, n: int) -> tuple[dict, float]:
    """(no-fault streams by uid, single-engine makespan) for a seeded
    workload — the parity oracle and the horizon faults are pinned
    against. Memoized: the reference is placement-independent."""
    key = (seed, n)
    if key not in _REF_CACHE:
        ref = make_engine(served, "tpu_v5e")
        for r in workload(seed, n):
            ref.submit(r)
        streams = {r.uid: list(r.tokens) for r in ref.run_until_empty()}
        _REF_CACHE[key] = (streams, ref.report()["model_s"])
    return _REF_CACHE[key]


# ---------------------------------------------------------------------------
# the core chaos property check
# ---------------------------------------------------------------------------


def _check_chaos(served, seed: int, n: int, results, sched,
                 *, allow_non_ok: bool = False):
    """Assert the exactly-once, provenance, parity, and ledger
    invariants after a (possibly faulty) fleet run."""
    reqs = workload(seed, n)
    rep = sched.report()
    log = sched.request_log

    # exactly-once: one terminal disposition per submitted request
    assert sorted(log) == sorted(r.uid for r in reqs)
    ok_uids = sorted(r.uid for r in results)
    assert len(set(ok_uids)) == len(ok_uids)
    assert ok_uids == sorted(u for u, d in log.items()
                             if d["status"] == "ok")
    if not allow_non_ok:
        assert all(d["status"] == "ok" for d in log.values())

    # provenance: finished on the member it was (last) routed to
    for r in results:
        assert log[r.uid]["engine"] == sched.routed_to[r.uid]

    # stream integrity: bit-identical to the no-fault reference —
    # migration continues the exact state, replay forces the exact
    # prefix, and greedy continuation is deterministic either way
    streams, _ = _reference(served, seed, n)
    for r in results:
        np.testing.assert_array_equal(
            r.tokens, streams[r.uid],
            err_msg=f"uid {r.uid} stream diverged under faults")

    # ledger conservation: fleet total still sums from the members
    # (lost replayed spend rides in the failed member's idle share)
    ledger = sum(e["engine"]["energy_j"] + e["gap_idle_j"]
                 for e in rep["engines"].values())
    np.testing.assert_allclose(rep["fleet_energy_j"], ledger, rtol=1e-9)
    attributed = sum(r.energy_j for r in results)
    assert rep["fleet_energy_j"] >= attributed - 1e-9
    assert rep["faults"]["lost_energy_j"] >= 0.0
    return rep, log


def _run_chaos(served, seed: int, n: int, slo, plan, **fleet_kw):
    sched = make_fleet(served, slo=slo, fault_plan=plan, **fleet_kw)
    for r in workload(seed, n):
        sched.submit(r)
    results = sched.run_until_empty()
    return results, sched


# ---------------------------------------------------------------------------
# hypothesis tier (skipped when the package is absent)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 2**16 - 1), n=st.integers(2, 5),
           slo=st.sampled_from([0.5, None]),
           plan_seed=st.integers(0, 2**16 - 1))
    def test_chaos_invariants_hypothesis(seed, n, slo, plan_seed):
        served = _get_served()
        _, horizon = _reference(served, seed, n)
        plan = FaultPlan.random(["v5e", "ada"], plan_seed,
                                horizon_s=max(horizon, 1e-6))
        results, sched = _run_chaos(served, seed, n, slo, plan)
        _check_chaos(served, seed, n, results, sched)


# ---------------------------------------------------------------------------
# deterministic fallback tier (always runs, hypothesis or not)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n,slo,plan_seed", [
    (11, 5, 0.5, 101),
    (29, 4, None, 7),       # best-effort under chaos
])
def test_chaos_invariants_seeded(served, seed, n, slo, plan_seed):
    _, horizon = _reference(served, seed, n)
    plan = FaultPlan.random(["v5e", "ada"], plan_seed,
                            horizon_s=max(horizon, 1e-6))
    results, sched = _run_chaos(served, seed, n, slo, plan)
    _check_chaos(served, seed, n, results, sched)


# ---------------------------------------------------------------------------
# targeted recovery paths
# ---------------------------------------------------------------------------


def _step_until_resident(sched, name: str, budget: int = 500):
    """Drive the scheduler until member `name` holds live decode
    state; returns the results retired along the way."""
    out = []
    m = sched.members[name]
    for _ in range(budget):
        out.extend(sched.step())
        lv = m.engine._live
        if lv is not None and any(s is not None for s in lv.slots):
            return out
    pytest.skip(f"{name} never held a decode slot")


def test_crash_with_state_migrates_bit_identical(served):
    """A crash that preserves device state migrates every resident
    request to the compatible survivor: streams bit-identical to the
    no-fault run, zero replays for the migrated rows."""
    seed, n = 7, 6
    sched = make_fleet(served, slo=0.5)
    for r in workload(seed, n):
        sched.submit(r)
    results = _step_until_resident(sched, "v5e")
    sched._fail_member(sched.members["v5e"], evict=False,
                       state_lost=False)
    assert sched._recovery, "crash with in-flight work must checkpoint"
    had_state = sum(1 for rec in sched._recovery
                    if rec.get("state") is not None)
    results += sched.run_until_empty()
    rep, log = _check_chaos(served, seed, n, results, sched)
    assert rep["faults"]["crashes"] == 1
    assert rep["engines"]["v5e"]["crashed"]
    if had_state:
        assert rep["faults"]["migrations"] >= had_state
        assert any(d["migrations"] > 0 for d in log.values())
    # the dead member's idle-floor horizon truncates at the crash
    assert (rep["engines"]["v5e"]["gap_idle_model_s"]
            <= rep["makespan_model_s"] + 1e-12)


def test_crash_state_lost_replays_append_only(served):
    """Losing device state with the node forces replay: requests
    requeue with their emitted tokens as a forced prefix (streams stay
    append-only and land bit-identical), the retry pays backoff, and
    the failed attempt's spend is charged as lost energy."""
    seed, n = 13, 6
    sched = make_fleet(served, slo=0.5)
    for r in workload(seed, n):
        sched.submit(r)
    results = _step_until_resident(sched, "ada")
    sched._fail_member(sched.members["ada"], evict=False,
                       state_lost=True)
    emitted = {rec["req"].uid: list(rec["tokens"])
               for rec in sched._recovery}
    assert any(toks for toks in emitted.values())
    results += sched.run_until_empty()
    rep, log = _check_chaos(served, seed, n, results, sched)
    assert rep["faults"]["migrations"] == 0
    assert rep["faults"]["replays"] >= len(emitted)
    assert rep["faults"]["lost_energy_j"] > 0.0
    final = {r.uid: list(r.tokens) for r in results}
    for uid, prefix in emitted.items():
        assert final[uid][:len(prefix)] == prefix, \
            f"uid {uid}: replay rewrote already-streamed tokens"
        assert log[uid]["retries"] >= 1


def test_stall_is_detected_and_evicted(served):
    """A stall injected through the plan is *detected* via the
    straggler EWMAs over observed/predicted step ratios — the scheduler
    never reads the schedule — and the flagged member is evicted with
    its work migrated; streams stay bit-identical."""
    seed, n = 23, 8
    plan = FaultPlan([FaultEvent(0.0, "stall", "ada", factor=8.0,
                                 duration_s=1e9)])
    results, sched = _run_chaos(
        served, seed, n, 0.5, plan,
        straggler_cfg=StragglerConfig(patience=2))
    rep, _ = _check_chaos(served, seed, n, results, sched)
    assert rep["faults"]["stalls"] == 1
    assert rep["faults"]["evictions"] >= 1
    assert rep["engines"]["ada"]["evictions"] >= 1


def test_artifact_corruption_degrades_not_fails(served):
    """Mid-run predictor-artifact corruption downgrades the member's
    tuning to BASELINE configs: serving continues, the report flags the
    degraded mode, and streams are bit-identical to a healthy run
    (block configs price work; they never change tokens)."""
    seed, n = 31, 5
    _, horizon = _reference(served, seed, n)
    plan = FaultPlan([FaultEvent(0.3 * horizon, "artifact_corruption",
                                 "v5e")])
    results, sched = _run_chaos(served, seed, n, 0.5, plan)
    rep, _ = _check_chaos(served, seed, n, results, sched)
    assert rep["faults"]["degraded_members"] == ["v5e"]
    assert rep["engines"]["v5e"]["tuning_degraded"]
    assert not rep["engines"]["ada"]["tuning_degraded"]


def test_retune_injected_artifact_error_falls_back_to_baseline(served):
    eng = make_engine(served)
    ok = eng.retune(_inject=ArtifactError("chaos: corrupt artifact"))
    assert not ok
    assert eng.tuning_degraded
    assert eng.pretuned and all(c == BASELINE
                                for c in eng.pretuned.values())
    rep = eng.report()
    assert rep["tuning_degraded"]
    assert "corrupt" in rep["tuning_degraded_reason"]


def test_checkpoint_adopt_roundtrip_engine_level(served):
    """The slot-surgery primitive under the scheduler: checkpointed
    rows adopted by a compatible engine (plus replays for the rest)
    reproduce the reference streams exactly, and the failed engine is
    left empty."""
    seed, n = 41, 4
    streams, _ = _reference(served, seed, n)
    src = make_engine(served, "tpu_v5e")
    for r in workload(seed, n):
        src.submit(r)
    done = []
    while src.has_work:
        done.extend(src.serve_step())
        lv = src._live
        if lv is not None and any(s is not None for s in lv.slots):
            break
    records = src.checkpoint_inflight()
    assert not src.has_work and records
    dst = make_engine(served, "tpu_v5e")
    assert dst.state_compatible(src)
    for rec in records:
        if rec["state"] is not None:
            dst.adopt(rec)
        else:
            req = rec["req"]
            req.replay = list(rec["tokens"]) or None
            dst.submit(req)
    while dst.has_work:
        done.extend(dst.serve_step())
    assert sorted(r.uid for r in done) == sorted(streams)
    for r in done:
        np.testing.assert_array_equal(r.tokens, streams[r.uid])


def test_replay_prefix_continues_stream_engine_level(served):
    """A fresh engine serving a request with a forced replay prefix
    emits exactly the reference stream (prefix re-emitted, greedy tail
    identical)."""
    prompt = np.arange(10, dtype=np.int32)
    ref_eng = make_engine(served)
    ref_eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    (ref_r,) = ref_eng.run_until_empty()
    ref = list(ref_r.tokens)
    assert len(ref) >= 2
    req = Request(uid=0, prompt=prompt, max_new_tokens=6,
                  replay=list(ref[:2]))
    eng = make_engine(served)
    eng.submit(req)
    out = []
    while eng.has_work:
        out.extend(eng.serve_step())
    (r,) = out
    np.testing.assert_array_equal(r.tokens, ref)


def test_replay_rejected_off_the_chunked_path(served):
    """Replay is a chunked-admission (serve_step) contract; the serial
    and wave paths refuse it loudly instead of double-emitting."""
    req = workload(53, 1)[0]
    req.replay = [1, 2]
    eng = make_engine(served)
    eng.submit(req)
    with pytest.raises(ValueError, match="replay"):
        eng.run_wave()


# ---------------------------------------------------------------------------
# overload admission control
# ---------------------------------------------------------------------------


def test_shed_policy_records_terminal_disposition(served):
    """An unattainable SLO with policy='shed' rejects every request —
    each still gets exactly one logged disposition, and the per-class
    counters match."""
    n = 4
    sched = make_fleet(
        served, slo=0.5,
        sla={"interactive": SLAClass("interactive", 1e-12,
                                     policy="shed")})
    for r in workload(61, n):
        sched.submit(r)
    results = sched.run_until_empty()
    assert results == []
    log = sched.request_log
    assert len(log) == n
    assert all(d["status"] == "shed" for d in log.values())
    rep = sched.report()
    assert rep["sla"]["interactive"]["shed"] == n
    assert rep["faults"]["shed"] == {"interactive": n}
    assert rep["requests"] == n


def test_defer_policy_backs_off_then_accepts(served):
    """policy='defer' rotates infeasible admissions with capped
    backoff, then accepts late rather than starving — every request
    still completes exactly once, streams unchanged."""
    seed, n = 67, 4
    sched = make_fleet(
        served, slo=0.5,
        sla={"interactive": SLAClass("interactive", 1e-12,
                                     policy="defer", defer_s=0.01,
                                     max_defers=2)})
    for r in workload(seed, n):
        sched.submit(r)
    results = sched.run_until_empty()
    rep, log = _check_chaos(served, seed, n, results, sched)
    assert len(results) == n
    assert rep["sla"]["interactive"]["deferred"] >= 1
    assert rep["faults"]["shed"] == {}


def test_backlog_watermark_triggers_admission_control(served):
    """Crossing `admission_watermark_tokens` applies the SLA policy
    even when placements are predicted feasible (the overload valve)."""
    seed, n = 71, 4
    sched = make_fleet(
        served, slo=0.5,
        sla={"interactive": SLAClass("interactive", 1e6,
                                     policy="defer", defer_s=0.01,
                                     max_defers=3)},
        admission_watermark_tokens=1)
    for r in workload(seed, n):
        sched.submit(r)
    results = sched.run_until_empty()
    rep, _ = _check_chaos(served, seed, n, results, sched)
    assert len(results) == n
    assert rep["sla"]["interactive"]["deferred"] >= 1
    loose = make_fleet(served, slo=1e6)
    for r in workload(seed, n):
        loose.submit(r)
    loose.run_until_empty()
    assert loose.report()["sla"]["interactive"]["deferred"] == 0


# ---------------------------------------------------------------------------
# page-pool pressure + registry shedding
# ---------------------------------------------------------------------------


def test_page_pressure_squeeze_unsqueeze():
    alloc = PageAllocator(8, 4)            # page 0 reserved: 7 usable
    assert alloc.squeeze(3) == 3
    assert alloc.free_pages == 4
    assert alloc.stats["squeezed"] == 3
    assert alloc.squeeze(100) == 4        # clamped to the free list
    assert alloc.free_pages == 0
    assert alloc.unsqueeze() == 7
    assert alloc.free_pages == 7
    assert alloc.stats["squeezed"] == 0


def test_registry_shed_frees_pages_and_counts():
    alloc = PageAllocator(8, 4)
    prompt = np.arange(8, dtype=np.int32)
    pages = alloc.alloc(2)
    alloc.register(prompt, pages, written=8)
    assert alloc.match(prompt)[1] > 0      # registry is live
    before = alloc.free_pages
    shed = alloc.shed_registry()
    assert shed >= 1
    assert alloc.stats["registry_sheds"] == shed
    assert alloc.free_pages >= before      # registry refs released
    assert alloc.match(prompt)[1] == 0     # cold after the shed
    alloc.release(pages)
    assert alloc.free_pages == 7           # nothing leaked (page 0 held)


def test_page_pressure_requires_paged_engine(served):
    eng = make_engine(served)              # dense layout
    with pytest.raises(ValueError, match="paged"):
        eng.inject_page_pressure(2)
    paged = make_engine(served, kv_layout="paged", page_size=8)
    assert paged.inject_page_pressure(2) == 2
    assert paged.release_page_pressure() == 2


# ---------------------------------------------------------------------------
# the fault plan itself
# ---------------------------------------------------------------------------


def test_fault_plan_due_pops_in_order():
    plan = FaultPlan([FaultEvent(2.0, "crash", "b"),
                      FaultEvent(1.0, "stall", "a", factor=4.0)])
    assert plan.due(0.5) == []
    fired = plan.due(1.5)
    assert [e.kind for e in fired] == ["stall"]
    assert plan.remaining == 1
    assert [e.kind for e in plan.due(10.0)] == ["crash"]
    assert plan.due(10.0) == []
    assert len(plan) == 2


def test_fault_plan_random_is_deterministic_and_keeps_a_survivor():
    members = ["a", "b"]
    p1 = FaultPlan.random(members, 5, horizon_s=1.0, n_events=10,
                          kinds=("crash",))
    p2 = FaultPlan.random(members, 5, horizon_s=1.0, n_events=10,
                          kinds=("crash",))
    assert p1.report() == p2.report()
    crashes = [e for e in p1._events if e.kind == "crash"]
    assert len(crashes) <= 1               # never the whole fleet
    assert all(0.0 <= e.t_model_s <= 1.0 for e in p1._events)


def test_fault_plan_report_tracks_fired():
    plan = FaultPlan([FaultEvent(1.0, "stall", "a", factor=2.0)], seed=9)
    rep = plan.report()
    assert rep["seed"] == 9 and not rep["events"][0]["fired"]
    plan.due(2.0)
    assert plan.report()["events"][0]["fired"]


def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(0.0, "meteor", "a")
    with pytest.raises(ValueError, match="factor"):
        FaultEvent(0.0, "stall", "a", factor=1.0)


def test_retry_backoff_caps():
    assert retry_backoff_s(1) == 0.05
    assert retry_backoff_s(2) == 0.1
    assert retry_backoff_s(20) == 1.0      # capped
    assert retry_backoff_s(3, base_s=0.01, cap_s=0.02) == 0.02
    with pytest.raises(ValueError):
        retry_backoff_s(0)
