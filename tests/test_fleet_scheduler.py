"""Fleet-scheduler property suite: scheduler invariants that must hold
for *any* workload before predictor-driven placement can be trusted.

Conservation: every admitted request completes exactly once, on the
engine it was routed to, and per-request energy attribution sums to the
fleet ledger within fp tolerance (the ledger additionally carries
engine-idle and parked-gap energy, so fleet totals are a strict upper
bound on attributed energy). Routing invariance: a request's greedy
token stream is bit-identical no matter which engine serves it at tp=1
— engines share params and sampling seed, and the engine contract makes
streams batch-composition-independent — so the scheduler's placement
choices can never change tokens, only latency and energy.

Runs under hypothesis when available (drawing workload seeds and SLO
knobs); falls back to a deterministic seed sweep otherwise — the same
two-tier pattern as `tests/test_compiled_parity.py`.
"""

from __future__ import annotations

from collections import Counter

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import FleetScheduler, SLAClass

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="fleet-test", kind="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        param_dtype="float32", activation_dtype="float32", remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


_SERVED_CACHE: dict = {}


def _get_served():
    """Memoized (cfg, model, params) triple shared by every test (and
    by the hypothesis tier, which bypasses fixture injection)."""
    if "served" not in _SERVED_CACHE:
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        _SERVED_CACHE["served"] = (cfg, model, params)
    return _SERVED_CACHE["served"]


@pytest.fixture(scope="module")
def served():
    return _get_served()


def make_engine(served, chip: str = "tpu_v5e", **kw) -> ServingEngine:
    cfg, model, params = served
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("chunk_tokens", 16)
    kw.setdefault("seed", 0)
    return ServingEngine(model, params, cfg, chip=chip, **kw)


def make_fleet(served, slo: float | None = 0.5,
               **sched_kw) -> FleetScheduler:
    """Two-member heterogeneous fleet (TPU v5e + RTX 4070) sharing
    params and sampling seed, with one TTFT class when `slo` is set."""
    engines = {"v5e": make_engine(served, "tpu_v5e"),
               "ada": make_engine(served, "rtx4070")}
    if slo is None:
        return FleetScheduler(engines, **sched_kw)
    sched_kw.setdefault("default_sla", "interactive")
    return FleetScheduler(
        engines, sla={"interactive": SLAClass("interactive", slo)},
        **sched_kw)


def workload(seed: int, n: int, lo: int = 3, hi: int = 40,
             max_budget: int = 8) -> list[Request]:
    """Deterministic mixed-length workload (fresh Request objects per
    call — submission stamps them)."""
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(0, 256, int(rng.integers(lo, hi))
                                    ).astype(np.int32),
                max_new_tokens=int(rng.integers(1, max_budget + 1)))
        for i in range(n)]


# ---------------------------------------------------------------------------
# the core property check
# ---------------------------------------------------------------------------


def _check_fleet(served, seed: int, n: int, slo: float | None):
    """Serve a seeded workload across the fleet and assert every
    conservation invariant; returns (results, scheduler)."""
    sched = make_fleet(served, slo=slo)
    reqs = workload(seed, n)
    for r in reqs:
        sched.submit(r)
    results = sched.run_until_empty()
    rep = sched.report()
    log = sched.request_log

    # every admitted request completes exactly once
    uids = sorted(r.uid for r in results)
    assert uids == sorted(r.uid for r in reqs)
    assert len(set(uids)) == len(uids)
    assert rep["requests"] == n

    # no engine serves a request it was never routed (provenance is
    # enforced at retirement; counters must agree end to end)
    routed = Counter(sched.routed_to.values())
    assert sum(routed.values()) == n
    for name, e in rep["engines"].items():
        assert e["completed"] == routed.get(name, 0)
        assert e["engine"]["requests"] == routed.get(name, 0)
    for r in results:
        assert log[r.uid]["engine"] == sched.routed_to[r.uid]

    # per-request energy sums to the fleet's attributed total, and the
    # fleet ledger is attributed + engine-idle + parked-gap energy
    attributed = sum(r.energy_j for r in results)
    eng_attr = sum(e["engine"]["attributed_energy_j"]
                   for e in rep["engines"].values())
    np.testing.assert_allclose(attributed, eng_attr, rtol=1e-9, atol=1e-12)
    ledger = sum(e["engine"]["energy_j"] + e["gap_idle_j"]
                 for e in rep["engines"].values())
    np.testing.assert_allclose(rep["fleet_energy_j"], ledger, rtol=1e-9)
    assert rep["fleet_energy_j"] >= attributed - 1e-9

    # token accounting
    assert rep["generated_tokens"] == sum(r.n_tokens for r in results)
    assert all(d["met_slo"] in (True, False) for d in log.values())
    return results, sched


def _check_parity(served, seed: int, n: int, slo: float | None):
    """Routing invariance: fleet streams must be bit-identical to one
    reference engine serving the same workload alone at tp=1."""
    results, _ = _check_fleet(served, seed, n, slo)
    ref = make_engine(served, "tpu_v5e")
    for r in workload(seed, n):
        ref.submit(r)
    ref_streams = {r.uid: r.tokens for r in ref.run_until_empty()}
    for r in results:
        np.testing.assert_array_equal(
            r.tokens, ref_streams[r.uid],
            err_msg=f"uid {r.uid} stream depends on placement")


# ---------------------------------------------------------------------------
# hypothesis tier (skipped when the package is absent)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**16 - 1), n=st.integers(2, 7),
           slo=st.sampled_from([0.05, 0.5, None]))
    def test_fleet_invariants_hypothesis(seed, n, slo):
        _check_parity(_get_served(), seed, n, slo)


# ---------------------------------------------------------------------------
# deterministic fallback tier (always runs, hypothesis or not)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n,slo", [
    (11, 6, 0.5),
    (29, 5, 0.02),      # tight SLO: misses allowed, invariants not
    (47, 4, None),      # best-effort only
])
def test_fleet_invariants_seeded(served, seed, n, slo):
    _check_parity(served, seed, n, slo)


# ---------------------------------------------------------------------------
# targeted scheduler behaviors
# ---------------------------------------------------------------------------


def test_single_engine_baseline_parks_the_rest(served):
    """`route_to` forces every request to one member; the others serve
    nothing and their gap-idle energy covers the whole makespan."""
    sched = make_fleet(served, route_to="ada")
    for r in workload(5, 4):
        sched.submit(r)
    results = sched.run_until_empty()
    rep = sched.report()
    assert len(results) == 4
    assert rep["engines"]["v5e"]["routed"] == 0
    assert rep["engines"]["v5e"]["busy_model_s"] == 0.0
    np.testing.assert_allclose(rep["engines"]["v5e"]["gap_idle_model_s"],
                               rep["makespan_model_s"], rtol=1e-9)
    assert rep["engines"]["ada"]["completed"] == 4


def test_race_to_idle_drains_expensive_engine(served):
    """With a loose SLO and a queue the cheap engine can absorb, the
    most expensive member is drained and ends the run parked — and the
    invariants still hold."""
    results, sched = _check_fleet(served, seed=3, n=10, slo=30.0)
    rep = sched.report()
    assert len(results) == 10
    assert rep["drains"] >= 1
    assert rep["attainment"] == 1.0
    drained = [n for n, e in rep["engines"].items() if e["drains"]]
    assert all(rep["engines"][n]["parked"] for n in drained)


def test_chunk_policy_installed_and_scoped(served):
    """The scheduler installs a per-member chunk policy; engines keep
    SJF when no SLO-classed rows are pending (policy returns None)."""
    sched = make_fleet(served, slo=None)
    for m in sched.members.values():
        assert m.engine.chunk_policy is not None
        assert m.engine.chunk_policy(m.engine, [(Request(
            uid=99, prompt=np.zeros(4, np.int32)), 4)]) is None


def test_scheduler_rejects_unsteppable_engine(served):
    with pytest.raises(ValueError, match="steppable"):
        FleetScheduler({"w": make_engine(served, mode="wave")})


def test_scheduler_validates_sla_names(served):
    with pytest.raises(ValueError, match="default_sla"):
        make_fleet(served, slo=0.5, default_sla="nope")
    sched = make_fleet(served, slo=0.5)
    with pytest.raises(ValueError, match="unknown SLA"):
        sched.submit(Request(uid=0, prompt=np.zeros(4, np.int32)),
                     sla="bulk")


def test_reset_stats_rezeroes_ledger(served):
    sched = make_fleet(served, slo=0.5)
    for r in workload(13, 3):
        sched.submit(r)
    sched.run_until_empty()
    sched.reset_stats()
    rep = sched.report()
    assert rep["requests"] == 0
    assert rep["fleet_energy_j"] == 0.0
    assert rep["makespan_model_s"] == 0.0
    for r in workload(17, 3):
        sched.submit(r)
    assert len(sched.run_until_empty()) == 3


def test_draining_fleet_cannot_livelock(served):
    """Regression for the run_until_empty livelock edge: work pending
    while every member sits parked *and draining* (race-to-idle's end
    state). `_candidates` excludes draining members at every widen
    level, and nothing in the old `step()` ever cleared the flag — so
    driving `step()` directly spun forever, returning [] with a
    non-empty queue. The rescue pass must wake a member (clearing its
    drain) or shed per policy; bounded stepping must finish the
    request."""
    sched = make_fleet(served, slo=0.5)
    now = sched.fleet_now()
    for m in sched.members.values():
        m.draining = True
        sched._park(m, now)
    sched.submit(Request(uid=0, prompt=np.zeros(6, np.int32),
                         max_new_tokens=3))
    results = []
    for _ in range(200):
        results.extend(sched.step())
        if results:
            break
    assert [r.uid for r in results] == [0], \
        "scheduler livelocked with a draining fleet and pending work"
    assert sched.request_log[0]["status"] == "ok"


def test_serve_step_contract(served):
    """The engine stepper the scheduler stands on: steps interleave
    with submissions, yield per-step retirements, and drain exactly the
    run_until_empty stream."""
    eng = make_engine(served)
    stepped: list = []
    for r in workload(21, 3):
        eng.submit(r)
        while eng.has_work:
            out = eng.serve_step()
            stepped.extend(out)
            if out:
                break               # interleave next submit mid-flight
    while eng.has_work:
        stepped.extend(eng.serve_step())
    ref = make_engine(served)
    for r in workload(21, 3):
        ref.submit(r)
    ref_streams = {r.uid: r.tokens for r in ref.run_until_empty()}
    assert sorted(r.uid for r in stepped) == sorted(ref_streams)
    for r in stepped:
        np.testing.assert_array_equal(r.tokens, ref_streams[r.uid])
