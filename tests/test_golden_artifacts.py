"""Golden-artifact regression tests.

Tiny fitted predictor artifacts (one per model family) are committed under
``tests/fixtures/`` together with their expected predictions on a frozen
input block. Loading them exercises the full validated artifact path
(schema version, feature/target schema, fingerprint), and the prediction
assertions pin the numeric outputs of both the numpy stacked-descent path
and the compiled x64 scorer:

  * a feature-schema change makes `PerfPredictor.load` raise
    `ArtifactError` -> the suite fails until fixtures are regenerated
    (the intended "schema bumps are explicit" CI gate);
  * a descent/serialization change that silently shifts predictions
    fails the output comparison.

Regenerate deliberately with ``python tests/gen_golden_fixtures.py``.
"""

import os

import numpy as np
import pytest

from gen_golden_fixtures import FIXTURE_DIR, GOLDEN_CHIP, GOLDEN_FAMILIES


@pytest.fixture(scope="module")
def expected():
    path = os.path.join(FIXTURE_DIR, "golden_expected.npz")
    with np.load(path, allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


@pytest.mark.parametrize("family", GOLDEN_FAMILIES)
def test_golden_artifact_load_and_predict(family, expected):
    from repro.core.predictor import PerfPredictor

    pred = PerfPredictor.load(
        os.path.join(FIXTURE_DIR, f"golden_{family}.npz"))
    assert pred.model_name == family
    assert pred.chip_name == GOLDEN_CHIP
    assert list(expected["feature_names"]) == list(pred.feature_names)
    assert list(expected["target_names"]) == list(pred.target_names)

    X = expected["X"]
    table = {name: X[:, i] for i, name in enumerate(pred.feature_names)}
    got = pred.predict_matrix(table)
    np.testing.assert_allclose(got, expected[f"{family}/predict"],
                               rtol=1e-9)

    got_jit = np.asarray(pred.jax_predictor(x64=True)(X))
    np.testing.assert_allclose(got_jit, expected[f"{family}/jit_x64"],
                               rtol=1e-9)


def test_golden_ridge_state_roundtrip(expected):
    from repro.core.mlperf import Ridge, estimator_from_state

    path = os.path.join(FIXTURE_DIR, "golden_ridge_state.npz")
    with np.load(path, allow_pickle=False) as z:
        state = {k: z[k] for k in z.files}
    ridge = estimator_from_state(state)
    assert isinstance(ridge, Ridge)
    got = ridge.predict(expected["ridge/X"])
    np.testing.assert_array_equal(got, expected["ridge/predict"])

    from repro.core.mlperf.jaxpredict import JaxEstimator

    got_jit = JaxEstimator(ridge, x64=True).predict(expected["ridge/X"])
    np.testing.assert_allclose(
        got_jit, np.asarray(expected["ridge/predict"]).reshape(len(got), -1),
        rtol=1e-12)


def test_golden_artifacts_stay_tiny():
    """Committed fixtures must stay lightweight (they live in git)."""
    total = 0
    for name in os.listdir(FIXTURE_DIR):
        total += os.path.getsize(os.path.join(FIXTURE_DIR, name))
    assert total < 512 * 1024, f"fixtures grew to {total} bytes"
