"""Tests for the trip-count-aware HLO cost analyzer — validated against
real compiled programs with hand-computable costs."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.hloanalyze import analyze_hlo, parse_hlo

L, B, D = 8, 16, 64


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


@pytest.fixture(scope="module")
def scan_matmul_text():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    return _compile_text(f, jnp.ones((B, D)), jnp.ones((L, D, D)))


class TestFlops:
    def test_scan_flops_exact(self, scan_matmul_text):
        cost = analyze_hlo(scan_matmul_text, 1)
        assert cost.flops == pytest.approx(L * 2 * B * D * D, rel=1e-6)

    def test_trip_count_parsed(self, scan_matmul_text):
        cost = analyze_hlo(scan_matmul_text, 1)
        assert L in cost.while_trips.values()

    def test_nested_scan_multiplies(self):
        def g(x, ws):
            def outer(c, w):
                def inner(cc, _):
                    return cc @ w, None
                cc, _ = jax.lax.scan(inner, c, None, length=4)
                return cc, None
            y, _ = jax.lax.scan(outer, x, ws)
            return y.sum()

        text = _compile_text(g, jnp.ones((B, D)), jnp.ones((L, D, D)))
        cost = analyze_hlo(text, 1)
        assert cost.flops == pytest.approx(4 * L * 2 * B * D * D, rel=1e-6)

    def test_grad_with_remat_counts_recompute(self):
        def h(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            body = jax.checkpoint(body)
            y, _ = jax.lax.scan(body, x, ws)
            return (y ** 2).sum()

        text = _compile_text(jax.grad(h), jnp.ones((L, D, D)),
                             jnp.ones((B, D)))
        cost = analyze_hlo(text, 1)
        # fwd dot + recomputed dot + 2 backward dots per layer = 4 dots/layer
        assert cost.flops == pytest.approx(4 * L * 2 * B * D * D, rel=1e-6)

    def test_unrolled_matches_scanned(self):
        """Ground truth cross-check: unrolled python-loop model (no while
        loops, trivially countable) must match the scanned version."""
        ws = jnp.ones((L, D, D))
        x = jnp.ones((B, D))

        def unrolled(x, ws):
            for i in range(L):
                x = x @ ws[i]
            return x.sum()

        def scanned(x, ws):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()

        c_u = analyze_hlo(_compile_text(unrolled, x, ws), 1)
        c_s = analyze_hlo(_compile_text(scanned, x, ws), 1)
        assert c_u.flops == pytest.approx(c_s.flops, rel=1e-6)


class TestBytes:
    def test_bytes_bounded(self, scan_matmul_text):
        cost = analyze_hlo(scan_matmul_text, 1)
        # at least: weights read once (L*D*D*4) + carry read/write per step
        floor = L * D * D * 4
        ceil = 20 * floor
        assert floor <= cost.hbm_bytes <= ceil

    def test_kv_cache_dus_not_charged_full(self):
        """Scan that dus-updates one slice of a big carried buffer must not
        charge the full buffer per iteration."""
        S, n = 1024, 16

        def f(cache, xs):
            def body(c, i):
                c = jax.lax.dynamic_update_slice(c, xs[i][None], (i, 0))
                return c, c[i].sum()
            c, ys = jax.lax.scan(body, cache, jnp.arange(n))
            return ys.sum()

        # explicit f32: the 4-byte budget below must hold with or without
        # JAX_ENABLE_X64 (the x64 CI job runs this suite too)
        text = _compile_text(f, jnp.zeros((n, S), jnp.float32),
                             jnp.ones((n, S), jnp.float32))
        cost = analyze_hlo(text, 1)
        full_per_iter = n * S * 4 * n
        assert cost.hbm_bytes < 0.5 * full_per_iter


class TestCollectives:
    def test_psum_inside_scan_scaled(self):
        # collectives need >1 device to appear; just validate parser on text
        hlo = """
HloModule m
%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128] get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128]) tuple(%ip, %ar)
}
%cond (p: (s32[], f32[128])) -> pred[] {
  %p2 = (s32[], f32[128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}
ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128]{0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(%c0, %a)
  %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[128]{0} get-tuple-element(%w), index=1
}
"""
        cost = analyze_hlo(hlo, 4)
        operand = 128 * 4
        assert cost.collective_bytes == pytest.approx(
            10 * 2 * operand * 3 / 4)
        assert cost.while_trips.get("body") == 10


class TestParser:
    def test_parses_computations(self, scan_matmul_text):
        comps, symtab = parse_hlo(scan_matmul_text)
        assert any(c.is_entry for c in comps.values())
        assert len(comps) > 2
        assert symtab  # symbol table populated
