"""Tests for the analytical TPU GEMM simulator (the measurement substrate).

These assert the paper's qualitative phenomena hold in our TPU adaptation:
tiny tiles are pathological, there is an optimal mid-size tile, occupancy
falls off a VMEM cliff for huge tiles, power rises with utilization and is
TDP-capped, transposed layouts cost memory time.
"""

import math

import numpy as np
import pytest

from repro.core.chips import TPU_V5E
from repro.core.hwsim import GemmConfig, TpuGemmSimulator


@pytest.fixture
def sim():
    return TpuGemmSimulator(seed=0)


def _rt(sim, **kw):
    return sim.analyze(GemmConfig(**kw)).runtime_ms


class TestRuntimeModel:
    def test_runtime_grows_with_problem_size(self, sim):
        sizes = [512, 1024, 2048, 4096]
        times = [_rt(sim, m=s, n=s, k=s) for s in sizes]
        assert all(b > a for a, b in zip(times, times[1:]))

    def test_tiny_tile_pathological(self, sim):
        """Paper Figs 2-4: tile=1 is orders of magnitude slower. Our tile=8
        analogue (VPU fallback + grid flood) must be >=50x slower than 256."""
        slow = _rt(sim, m=2048, n=2048, k=2048, block_m=8, block_n=8, block_k=8)
        fast = _rt(sim, m=2048, n=2048, k=2048, block_m=256, block_n=256, block_k=256)
        assert slow > 50 * fast

    def test_plateau_after_moderate_tiles(self, sim):
        """Paper: runtime plateaus past tile 16; here once compute-bound
        (>=512 blocks for a 4096^3 GEMM)."""
        t512 = _rt(sim, m=4096, n=4096, k=4096, block_m=512, block_n=512, block_k=512)
        t1024 = _rt(sim, m=4096, n=4096, k=4096, block_m=1024, block_n=1024, block_k=512)
        assert abs(t1024 - t512) / t512 < 0.35

    def test_misaligned_block_wastes_mxu(self, sim):
        aligned = sim.analyze(GemmConfig(4096, 4096, 4096, 128, 128, 512))
        misaligned = sim.analyze(GemmConfig(4096, 4096, 4096, 100, 100, 500))
        assert misaligned.compute_time_ms > 1.5 * aligned.compute_time_ms

    def test_transposed_layout_increases_memory_time(self, sim):
        nn = sim.analyze(GemmConfig(4096, 4096, 4096, 256, 256, 512, layout="nn"))
        tt = sim.analyze(GemmConfig(4096, 4096, 4096, 256, 256, 512, layout="tt"))
        assert tt.memory_time_ms > nn.memory_time_ms * 1.3

    def test_beta_adds_output_traffic(self, sim):
        b0 = sim.analyze(GemmConfig(2048, 2048, 256, 256, 256, 256, beta=0.0))
        b1 = sim.analyze(GemmConfig(2048, 2048, 256, 256, 256, 256, beta=1.0))
        assert b1.memory_time_ms > b0.memory_time_ms

    def test_fp32_slower_than_bf16(self, sim):
        bf = _rt(sim, m=4096, n=4096, k=4096, dtype="bf16")
        f32 = _rt(sim, m=4096, n=4096, k=4096, dtype="f32")
        assert f32 > 1.5 * bf


class TestOccupancy:
    def test_vmem_cliff(self, sim):
        """Table I analogue: buffers collapse as block working set grows."""
        occ = sim.occupancy_report([128, 512, 1024, 2048])
        vals = [occ[t] for t in [128, 512, 1024, 2048]]
        assert all(a >= b for a, b in zip(vals, vals[1:]))
        assert occ[2048] <= 4
        assert occ[128] > 50

    def test_oversized_block_invalid(self, sim):
        t = sim.analyze(GemmConfig(8192, 8192, 8192, 4096, 4096, 4096))
        assert not t.valid
        assert t.max_inflight_buffers == 0

    def test_non_pipelined_when_single_buffer(self, sim):
        t = sim.analyze(GemmConfig(8192, 8192, 8192, 2048, 2048, 2048))
        if t.valid and t.max_inflight_buffers < 2:
            assert not t.pipelined


class TestPowerModel:
    def test_power_within_physical_range(self, sim):
        for s in [256, 1024, 4096]:
            t = sim.analyze(GemmConfig(s, s, s))
            assert TPU_V5E.idle_power_w * 0.9 <= t.power_w <= TPU_V5E.tdp_w

    def test_large_compute_bound_gemm_draws_more_power(self, sim):
        small = sim.analyze(GemmConfig(256, 256, 256))
        big = sim.analyze(GemmConfig(8192, 8192, 8192, 256, 256, 512))
        assert big.power_w > small.power_w + 20

    def test_energy_is_power_times_time(self, sim):
        t = sim.analyze(GemmConfig(2048, 2048, 2048))
        assert t.energy_j == pytest.approx(t.power_w * t.runtime_ms / 1e3, rel=1e-9)


class TestMeasurementNoise:
    def test_measurements_noisy_but_unbiased(self):
        sim = TpuGemmSimulator(seed=1, noise=0.03)
        cfg = GemmConfig(2048, 2048, 2048)
        truth = sim.analyze(cfg).runtime_ms
        xs = np.array([sim.measure(cfg).runtime_ms for _ in range(200)])
        assert xs.std() > 0
        assert abs(np.median(xs) - truth) / truth < 0.02

    def test_invalid_config_measures_nan(self):
        sim = TpuGemmSimulator(seed=0)
        t = sim.measure(GemmConfig(8192, 8192, 8192, 4096, 4096, 4096))
        assert not t.valid and math.isnan(t.runtime_ms)

    def test_temperature_rises_under_load(self):
        sim = TpuGemmSimulator(seed=0)
        t0 = sim.measure(GemmConfig(8192, 8192, 8192, 256, 256, 512)).temperature_c
        for _ in range(50):
            last = sim.measure(GemmConfig(8192, 8192, 8192, 256, 256, 512))
        assert last.temperature_c > t0
