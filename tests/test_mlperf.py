"""Unit tests for the from-scratch ML stack (trees/forest/gbdt/stacking)."""

import numpy as np
import pytest

from repro.core.mlperf import (
    Binner,
    DecisionTreeRegressor,
    GradientBoostedTreesRegressor,
    LinearRegression,
    Pipeline,
    RandomForestRegressor,
    StackingRegressor,
    StandardScaler,
    TabularPreprocessor,
    mae,
    mean_pct_error,
    median_pct_error,
    mse,
    r2_score,
    train_test_split,
)
from repro.core.mlperf.jaxpredict import JaxForestPredictor
from repro.core.mlperf.metrics import correlation_matrix, pearson_corr
from repro.core.mlperf.pipeline import compute_gemm_characteristics


def _toy(n=800, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, size=(n, 4))
    y = np.sin(X[:, 0] * 2) + X[:, 1] ** 2 - X[:, 2] * X[:, 3]
    y = y + 0.05 * rng.normal(size=n)
    return X, y


class TestMetrics:
    def test_r2_perfect(self):
        y = np.arange(10.0)
        assert r2_score(y, y) == pytest.approx(1.0)

    def test_r2_mean_predictor_zero(self):
        y = np.arange(10.0)
        assert r2_score(y, np.full_like(y, y.mean())) == pytest.approx(0.0)

    def test_mse_mae(self):
        y = np.array([1.0, 2.0])
        p = np.array([2.0, 0.0])
        assert mse(y, p) == pytest.approx(2.5)
        assert mae(y, p) == pytest.approx(1.5)

    def test_pct_errors(self):
        y = np.array([10.0, 100.0])
        p = np.array([11.0, 150.0])
        assert median_pct_error(y, p) == pytest.approx(30.0)
        assert mean_pct_error(y, p) == pytest.approx(30.0)

    def test_pearson(self):
        a = np.arange(100.0)
        assert pearson_corr(a, 3 * a + 1) == pytest.approx(1.0)
        assert pearson_corr(a, -a) == pytest.approx(-1.0)

    def test_correlation_matrix_shape(self):
        t = {"a": np.arange(10.0), "b": np.arange(10.0)[::-1], "c": np.ones(10)}
        m = correlation_matrix(t, ["a", "b"], ["a", "c"])
        assert m.shape == (2, 2)
        assert m[0, 0] == pytest.approx(1.0)


class TestBinner:
    def test_roundtrip_monotone(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(500, 3))
        b = Binner(64).fit(X)
        Xb = b.transform(X)
        assert Xb.dtype == np.uint8
        # binned order preserves raw order per column
        for j in range(3):
            order = np.argsort(X[:, j])
            assert (np.diff(Xb[order, j].astype(int)) >= 0).all()

    def test_missing_goes_to_reserved_bin(self):
        X = np.array([[1.0], [np.nan], [2.0]])
        b = Binner(8).fit(X)
        Xb = b.transform(X)
        assert Xb[1, 0] == 255


class TestTree:
    def test_fits_step_function(self):
        X = np.linspace(0, 1, 200)[:, None]
        y = (X[:, 0] > 0.5).astype(float)
        t = DecisionTreeRegressor(max_depth=2).fit(X, y)
        assert r2_score(y, t.predict(X)) > 0.99

    def test_multioutput(self):
        X, y = _toy()
        Y = np.stack([y, -2 * y], axis=1)
        t = DecisionTreeRegressor(max_depth=12).fit(X, Y)
        p = t.predict(X)
        assert p.shape == Y.shape
        assert r2_score(Y, p) > 0.8

    def test_feature_importance_finds_relevant(self):
        rng = np.random.default_rng(1)
        X = rng.normal(size=(500, 5))
        y = 10 * X[:, 2] + 0.01 * rng.normal(size=500)
        t = DecisionTreeRegressor(max_depth=4).fit(X, y)
        assert np.argmax(t.feature_importances_) == 2

    def test_sample_weight_zero_rows_ignored(self):
        X = np.array([[0.0], [1.0], [2.0], [3.0]])
        y = np.array([0.0, 0.0, 100.0, 0.0])
        w = np.array([1.0, 1.0, 1.0, 0.0])
        t = DecisionTreeRegressor(max_depth=3).fit(X, y, sample_weight=w)
        # row 3 has zero weight: prediction there should follow row 2's leaf
        assert t.predict(np.array([[3.0]]))[0] == pytest.approx(100.0)


class TestForest:
    def test_beats_linreg_on_nonlinear(self):
        X, y = _toy()
        Xtr, Xte, ytr, yte = train_test_split(X, y, random_state=0)
        f = RandomForestRegressor(n_estimators=30, max_depth=8, random_state=0).fit(Xtr, ytr)
        l = LinearRegression().fit(Xtr, ytr)
        assert r2_score(yte, f.predict(Xte)) > r2_score(yte, l.predict(Xte)) + 0.2

    def test_multioutput_shape(self):
        X, y = _toy(300)
        Y = np.stack([y, y + 1], axis=1)
        f = RandomForestRegressor(n_estimators=5, max_depth=4, random_state=0).fit(X, Y)
        assert f.predict(X).shape == (300, 2)

    def test_deterministic_given_seed(self):
        X, y = _toy(200)
        p1 = RandomForestRegressor(n_estimators=5, random_state=42).fit(X, y).predict(X)
        p2 = RandomForestRegressor(n_estimators=5, random_state=42).fit(X, y).predict(X)
        np.testing.assert_allclose(p1, p2)

    def test_jax_predict_matches_numpy(self):
        X, y = _toy(300)
        Y = np.stack([y, 2 * y], axis=1)
        f = RandomForestRegressor(n_estimators=8, max_depth=6, random_state=0).fit(X, Y)
        jp = JaxForestPredictor(f)
        np.testing.assert_allclose(jp.predict(X), f.predict(X), rtol=1e-4, atol=1e-4)


class TestGBDT:
    def test_improves_with_rounds(self):
        X, y = _toy()
        Xtr, Xte, ytr, yte = train_test_split(X, y, random_state=0)
        g = GradientBoostedTreesRegressor(n_estimators=150, max_depth=4, random_state=0)
        g.fit(Xtr, ytr)
        scores = g.staged_score_path(Xte, yte, lambda a, b: r2_score(a, b))
        assert scores[-1] > scores[4]
        assert scores[-1] > 0.8


class TestStacking:
    def test_stacking_at_least_matches_best_base(self):
        X, y = _toy(600)
        Xtr, Xte, ytr, yte = train_test_split(X, y, random_state=1)
        bases = [
            RandomForestRegressor(n_estimators=20, max_depth=6, random_state=0),
            GradientBoostedTreesRegressor(n_estimators=40, max_depth=3, random_state=0),
            LinearRegression(),
        ]
        s = StackingRegressor(bases, n_folds=4).fit(Xtr, ytr)
        r2_s = r2_score(yte, s.predict(Xte))
        best_base = max(
            r2_score(yte, b.fit(Xtr, ytr).predict(Xte)) for b in bases
        )
        assert r2_s > best_base - 0.05  # within noise of / better than best base


class TestPipeline:
    def test_scaler_roundtrip(self):
        X = np.random.default_rng(0).normal(5, 3, size=(100, 4))
        s = StandardScaler()
        Xs = s.fit_transform(X)
        assert np.allclose(Xs.mean(axis=0), 0, atol=1e-9)
        assert np.allclose(Xs.std(axis=0), 1, atol=1e-9)
        np.testing.assert_allclose(s.inverse_transform(Xs), X)

    def test_preprocessor_impute_clip_onehot(self):
        table = {
            "m": np.array([1.0, np.nan, 100.0, 2.0]),
            "layout": np.array(["nn", "nt", "nn", "tt"]),
        }
        tp = TabularPreprocessor(clip_quantiles=(0.0, 0.75))
        X = tp.fit_transform(table)
        names = tp.feature_names_
        assert "m" in names and "layout=nn" in names
        mcol = X[:, names.index("m")]
        assert np.isfinite(mcol).all()
        assert mcol.max() <= np.nanquantile(table["m"], 0.75) + 1e-9

    def test_gemm_characteristics(self):
        t = compute_gemm_characteristics({"m": [2], "n": [3], "k": [4]})
        assert t["total_flops"][0] == 48
        assert t["bytes_accessed"][0] == 4 * (8 + 12 + 6)

    def test_pipeline_end_to_end(self):
        rng = np.random.default_rng(0)
        table = {"m": rng.uniform(1, 10, 200), "n": rng.uniform(1, 10, 200)}
        y = table["m"] * table["n"]
        pipe = Pipeline(
            TabularPreprocessor(),
            RandomForestRegressor(n_estimators=20, max_depth=6, random_state=0),
        )
        pipe.fit(table, y)
        assert r2_score(y, pipe.predict(table)) > 0.8

    def test_train_test_split_dict(self):
        table = {"a": np.arange(10)}
        y = np.arange(10.0)
        ttr, tte, ytr, yte = train_test_split(table, y, test_size=0.3, random_state=0)
        assert len(ttr["a"]) == 7 and len(tte["a"]) == 3
        assert set(ttr["a"]) | set(tte["a"]) == set(range(10))
