"""Regression pins for MoE expert-capacity batch-composition coupling.

With a *binding* capacity factor (``cf < n_experts / top_k``), expert
capacity is sized from the whole batch, so which tokens an expert drops
depends on which *other* requests share the wave — serving a prompt
alone vs. next to a neighbor can change its greedy stream. That breaks
the batch-composition-independence contract the serving engine (and the
fleet scheduler's routing-invariance property) stands on, which is why
the engine only warns, and the fleet ladder keeps MoE capacity at
``E / K`` (non-binding: per-token top-k routing can never overflow).

These tests pin the behavior at both ends so a future capacity fix (or
an accidental regression) shows up loudly:

* at ``cf = E/K`` streams are batch-composition-independent — the
  invariant the rest of the stack relies on;
* at ``cf = 1.0`` the coupling is real today (pinned divergence seeds,
  found empirically with this exact config);
* per-row stream stability under a binding cf is the desired end state
  — xfail-documented until per-row capacity accounting lands
  (ROADMAP carried item).
"""

from __future__ import annotations

import warnings

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

VOCAB = 128
PROMPT_LEN = 12
# Seeds whose prompts provably steer expert routing past the binding
# capacity at cf=1.0 (found by sweep; at least one must keep diverging
# for the pin to hold — numerics differences may shift individuals).
DIVERGENT_SEEDS = (0, 1, 3)


def moe_cfg(capacity_factor: float) -> ModelConfig:
    return ModelConfig(
        name="moe-cap-test", kind="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=0, d_ff_expert=64, vocab=VOCAB,
        n_experts=4, top_k=1, capacity_factor=capacity_factor,
        param_dtype="float32", activation_dtype="float32", remat=False,
    )


def _served(capacity_factor: float):
    cfg = moe_cfg(capacity_factor)
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


@pytest.fixture(scope="module")
def moe_binding():
    """cf=1.0 < E/K=4: capacity binds, batch composition can couple."""
    return _served(1.0)


@pytest.fixture(scope="module")
def moe_safe():
    """cf=E/K: capacity can never bind for top-k routing."""
    return _served(4.0)


def _prompt(seed: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, VOCAB, PROMPT_LEN).astype(np.int32)


def _serve(served, prompts: dict[int, np.ndarray],
           mode: str = "wave") -> dict[int, np.ndarray]:
    """Serve the prompts in one engine (one wave when they fit the
    batch) and return uid -> greedy token stream."""
    cfg, model, params = served
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # binding-cf engine warning
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64,
                            mode=mode, seed=0)
    for uid, p in prompts.items():
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=8))
    return {r.uid: np.asarray(r.tokens) for r in eng.run_until_empty()}


def _alone_vs_paired(served, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Stream of prompt `seed` served alone vs. beside a neighbor."""
    a, b = _prompt(seed), _prompt(seed + 100)
    alone = _serve(served, {0: a})
    paired = _serve(served, {0: a, 1: b})
    return alone[0], paired[0]


def test_binding_capacity_couples_batch_composition(moe_binding):
    """Pin today's defect: under cf=1.0 at least one pinned seed's
    stream changes when a neighbor joins its wave. If this starts
    passing for all seeds, capacity became per-row — move the xfail
    guarantee below to a hard test and drop this pin."""
    diverged = []
    for seed in DIVERGENT_SEEDS:
        alone, paired = _alone_vs_paired(moe_binding, seed)
        if (alone.shape != paired.shape
                or not np.array_equal(alone, paired)):
            diverged.append(seed)
    assert diverged, (
        "binding-capacity composition coupling no longer reproduces at "
        f"seeds {DIVERGENT_SEEDS}; per-row capacity may have landed — "
        "promote the xfail guarantee to a hard test")


def test_nonbinding_capacity_is_composition_independent(moe_safe):
    """At cf=E/K every pinned seed's stream is identical alone vs.
    paired — the invariant the serving stack (and the fleet scheduler's
    routing-invariance property) requires of MoE families."""
    for seed in DIVERGENT_SEEDS:
        alone, paired = _alone_vs_paired(moe_safe, seed)
        np.testing.assert_array_equal(
            alone, paired,
            err_msg=f"seed {seed} diverged at non-binding capacity")


def test_nonbinding_capacity_continuous_matches_wave(moe_safe):
    """Continuous chunked admission reshuffles lane composition per
    step; at non-binding capacity the streams must still match the
    wave-mode reference bit for bit."""
    prompts = {i: _prompt(i) for i in DIVERGENT_SEEDS}
    wave = _serve(moe_safe, prompts, mode="wave")
    cont = _serve(moe_safe, prompts, mode="continuous")
    assert sorted(wave) == sorted(cont)
    for uid in wave:
        np.testing.assert_array_equal(wave[uid], cont[uid])


@pytest.mark.xfail(
    reason="per-row expert-capacity accounting not implemented: batch-"
           "level capacity lets a neighbor change which tokens an "
           "expert drops (ROADMAP carried item)",
    strict=False)
def test_binding_capacity_per_row_guarantee(moe_binding):
    """Desired end state: even a binding capacity factor must drop
    tokens per row, keeping streams composition-independent."""
    for seed in DIVERGENT_SEEDS:
        alone, paired = _alone_vs_paired(moe_binding, seed)
        np.testing.assert_array_equal(alone, paired)
