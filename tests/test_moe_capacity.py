"""Regression pins for MoE expert-capacity batch-composition independence.

Expert capacity is accounted PER ROW (sized from S, not the flattened
batch B*S), so which tokens an expert drops never depends on which other
requests share the wave — the batch-composition-independence contract the
serving engine (and the fleet scheduler's routing-invariance property)
stands on holds *unconditionally*, including under a binding capacity
factor (``cf < n_experts / top_k``).

These tests pin the guarantee at both ends:

* at ``cf = E/K`` (non-binding: per-token top-k routing can never
  overflow) streams are batch-composition-independent;
* at ``cf = 1.0`` (binding — capacity drops are real) streams are STILL
  batch-composition-independent, on seeds that provably exercised the
  old batch-level coupling;
* continuous chunked admission matches wave mode bit for bit at both
  capacity settings.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

VOCAB = 128
PROMPT_LEN = 12
# Seeds whose prompts provably steered expert routing past the binding
# capacity under the old batch-level accounting (found by sweep) — the
# exact workloads where composition coupling used to reproduce.
DIVERGENT_SEEDS = (0, 1, 3)


def moe_cfg(capacity_factor: float) -> ModelConfig:
    return ModelConfig(
        name="moe-cap-test", kind="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=0, d_ff_expert=64, vocab=VOCAB,
        n_experts=4, top_k=1, capacity_factor=capacity_factor,
        param_dtype="float32", activation_dtype="float32", remat=False,
    )


def _served(capacity_factor: float):
    cfg = moe_cfg(capacity_factor)
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


@pytest.fixture(scope="module")
def moe_binding():
    """cf=1.0 < E/K=4: capacity binds — drops happen, per row."""
    return _served(1.0)


@pytest.fixture(scope="module")
def moe_safe():
    """cf=E/K: capacity can never bind for top-k routing."""
    return _served(4.0)


def _prompt(seed: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, VOCAB, PROMPT_LEN).astype(np.int32)


def _serve(served, prompts: dict[int, np.ndarray],
           mode: str = "wave") -> dict[int, np.ndarray]:
    """Serve the prompts in one engine (one wave when they fit the
    batch) and return uid -> greedy token stream."""
    cfg, model, params = served
    eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64,
                        mode=mode, seed=0)
    for uid, p in prompts.items():
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=8))
    return {r.uid: np.asarray(r.tokens) for r in eng.run_until_empty()}


def _alone_vs_paired(served, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Stream of prompt `seed` served alone vs. beside a neighbor."""
    a, b = _prompt(seed), _prompt(seed + 100)
    alone = _serve(served, {0: a})
    paired = _serve(served, {0: a, 1: b})
    return alone[0], paired[0]


def test_binding_capacity_per_row_guarantee(moe_binding):
    """Even a binding capacity factor drops tokens per row, keeping
    streams composition-independent — on the seeds that used to diverge
    under batch-level capacity accounting."""
    for seed in DIVERGENT_SEEDS:
        alone, paired = _alone_vs_paired(moe_binding, seed)
        np.testing.assert_array_equal(
            alone, paired,
            err_msg=f"seed {seed} diverged under binding capacity — "
                    "per-row expert-capacity accounting regressed")


def test_nonbinding_capacity_is_composition_independent(moe_safe):
    """At cf=E/K every pinned seed's stream is identical alone vs.
    paired — the invariant the serving stack (and the fleet scheduler's
    routing-invariance property) requires of MoE families."""
    for seed in DIVERGENT_SEEDS:
        alone, paired = _alone_vs_paired(moe_safe, seed)
        np.testing.assert_array_equal(
            alone, paired,
            err_msg=f"seed {seed} diverged at non-binding capacity")


def test_nonbinding_capacity_continuous_matches_wave(moe_safe):
    """Continuous chunked admission reshuffles lane composition per
    step; at non-binding capacity the streams must still match the
    wave-mode reference bit for bit. (Under a *binding* cf, per-row
    capacity is a function of chunk length, so cross-chunk-grid parity
    is intentionally out of contract — composition independence, pinned
    above, is the guarantee.)"""
    prompts = {i: _prompt(i) for i in DIVERGENT_SEEDS}
    wave = _serve(moe_safe, prompts, mode="wave")
    cont = _serve(moe_safe, prompts, mode="continuous")
    assert sorted(wave) == sorted(cont)
    for uid in wave:
        np.testing.assert_array_equal(wave[uid], cont[uid])
