"""Paged KV cache tests: allocator edge cases (free-list exhaustion under a
full lane, refcount drop on mid-decode retire, COW fork on shared-prefix
divergence), paged-vs-dense attention-mask parity at page-boundary lengths,
scatter/gather primitive parity, and engine-level bit-parity + prefix reuse
for every paged family (dense / moe / mla_moe)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.paging import NULL_PAGE, PageAllocator, PageCacheFull


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="paged-test", kind="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=97, param_dtype="float32",
        activation_dtype="float32", remat=False,
    )
    if kw.get("kind") == "moe":
        base.update(n_experts=4, top_k=2, d_ff_expert=64,
                    capacity_factor=2.0)
    if kw.get("kind") == "mla_moe":
        base.update(n_experts=4, top_k=2, d_ff_expert=64,
                    capacity_factor=2.0, kv_lora_rank=16, rope_head_dim=8)
    if kw.get("kind") == "encdec":
        base.update(n_encoder_layers=2, gated_mlp=False)
    if kw.get("kind") == "vlm":
        base.update(qkv_bias=True, mrope=True, mrope_sections=(4, 2, 2))
    base.update(kw)
    return ModelConfig(**base)


def _extras(cfg: ModelConfig | None, uid: int) -> dict | None:
    """Admission extras for the modality families (None otherwise)."""
    if cfg is None or cfg.kind not in ("encdec", "vlm"):
        return None
    rng = np.random.default_rng(500 + uid)
    if cfg.kind == "encdec":
        t = 5 + 2 * (uid % 3)
        return {"src_embeds": rng.standard_normal(
            (t, cfg.d_model)).astype(np.float32)}
    grid = [(4, 4), (2, 3), None][uid % 3]
    if grid is None:
        return None
    gh, gw = grid
    return {"patch_embeds": rng.standard_normal(
        (gh * gw, cfg.d_model)).astype(np.float32), "grid_hw": grid}


def prompt(seed: int, n: int, vocab: int = 97) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


class TestPageAllocator:
    def test_null_page_reserved(self):
        alloc = PageAllocator(8, 4)
        pages = alloc.alloc(7)
        assert NULL_PAGE not in pages
        assert sorted(pages) == list(range(1, 8))

    def test_exhaustion_raises_and_rolls_back(self):
        """Free-list exhaustion must raise without leaking a partial grab:
        a failed alloc leaves the free list exactly as it found it."""
        alloc = PageAllocator(5, 4)
        held = alloc.alloc(2)
        free_before = alloc.free_pages
        with pytest.raises(PageCacheFull):
            alloc.alloc(3)
        assert alloc.free_pages == free_before
        alloc.release(held)
        assert alloc.free_pages == 4

    def test_refcount_frees_only_with_last_reader(self):
        """Refcount drop on mid-decode retire: a shared page released by
        one reader stays resident for the other and frees only when the
        last reference drops."""
        alloc = PageAllocator(8, 4)
        (page,) = alloc.alloc(1)
        alloc.retain([page])                 # second reader
        alloc.release([page])                # first retires mid-flight
        assert alloc.refs[page] == 1
        assert page not in alloc._free
        alloc.release([page])                # last reader retires
        assert alloc.refs[page] == 0
        assert page in alloc._free
        with pytest.raises(AssertionError):
            alloc.release([page])            # double free stays loud

    def test_admit_register_match_roundtrip(self):
        alloc = PageAllocator(64, 8)
        p1 = np.arange(20, dtype=np.int32)
        a1 = alloc.admit(p1, budget=4)
        assert a1.base == 0 and len(a1.pages) == 3
        copies = alloc.register(p1, a1.pages, len(p1))
        assert len(copies) == 1              # partial-page snapshot
        # a second prompt extending the full 20 tokens maps 2 full pages
        # plus the frozen partial snapshot (COW-forked: position 20 lands
        # inside it), so all 20 prefix tokens skip prefill
        p2 = np.concatenate([p1, 50 + np.arange(6, dtype=np.int32)])
        a2 = alloc.admit(p2, budget=4)
        assert a2.base == 20
        assert a2.pages[:2] == a1.pages[:2]
        assert alloc.stats["cow_forks"] == 1
        assert alloc.stats["prefix_hits"] == 1
        assert alloc.stats["prefix_hit_tokens"] == 20

    def test_cow_fork_when_prefix_diverges_mid_page(self):
        """COW fork: a reader that must write into a matched page (its
        prompt continues past a partial-page snapshot) gets a private
        copy — the registered page is never written."""
        alloc = PageAllocator(64, 8)
        p1 = np.arange(12, dtype=np.int32)   # 1 full page + 4-token tail
        a1 = alloc.admit(p1, budget=4)
        alloc.register(p1, a1.pages, len(p1))
        snap = alloc._partials[alloc._key(p1, 8)][1].page
        # same 12 tokens then diverges inside page 1 -> the snapshot page
        # matches (base 12) but position 12 lands inside it, so it forks
        p2 = np.concatenate([p1, 90 + np.arange(3, dtype=np.int32)])
        a2 = alloc.admit(p2, budget=4)
        assert a2.base == 12
        assert alloc.stats["cow_forks"] == 1
        assert a2.copies == [(snap, a2.pages[1])]
        assert a2.pages[1] != snap           # private writable fork
        assert alloc.refs[snap] == 1         # registry copy untouched

    def test_fully_matched_prompt_recomputes_last_token(self):
        """A prompt entirely covered by the registry still prefills >= 1
        token — sampling needs logits at the last prompt position."""
        alloc = PageAllocator(64, 8)
        p1 = np.arange(16, dtype=np.int32)
        a1 = alloc.admit(p1, budget=4)
        alloc.register(p1, a1.pages, len(p1))
        a2 = alloc.admit(p1.copy(), budget=4)
        assert a2.base == 15                 # clamped to plen - 1
        assert alloc.stats["cow_forks"] == 1  # page 1 gets written

    def test_eviction_reclaims_lru_registry_pages(self):
        """Under pressure, refcount-1 registry entries evict LRU-first;
        entries still shared with a live reader are not reclaimable."""
        alloc = PageAllocator(6, 4)          # 5 usable pages
        pa = alloc.admit(np.arange(4, dtype=np.int32), budget=1)
        alloc.register(np.arange(4, dtype=np.int32), pa.pages, 4)
        alloc.release(pa.pages)              # page now registry-only
        pb = alloc.admit(100 + np.arange(4, dtype=np.int32), budget=1)
        alloc.register(100 + np.arange(4, dtype=np.int32), pb.pages, 4)
        # pb's reader is still live: its chain entry is shared, pa's is
        # reclaimable. Demanding the rest of the pool must evict pa only.
        alloc.alloc(alloc.free_pages + 1)
        assert alloc.stats["evictions"] == 1
        assert len(alloc._chains) == 1
        with pytest.raises(PageCacheFull):
            alloc.alloc(1)                   # pb's entry survived


# ---------------------------------------------------------------------------
# primitives: scatter/gather and mask parity
# ---------------------------------------------------------------------------


class TestPagedPrimitives:
    def _pool_and_table(self, B=2, n=4, T=8, d=4, num_pages=None):
        P = num_pages or (B * n + 1)
        pool = jnp.zeros((P, T, d), jnp.float32)
        table = jnp.arange(1, B * n + 1, dtype=jnp.int32).reshape(B, n)
        return pool, table

    def test_scatter_gather_matches_dense_cache(self):
        """paged_cache_update + paged_gather == dense cache_update for
        every (index, length) straddling a page boundary."""
        B, n, T, d = 2, 4, 8, 4
        rng = np.random.default_rng(0)
        for idx, S in [(0, 8), (5, 8), (7, 1), (8, 1), (6, 4), (15, 2)]:
            pool, table = self._pool_and_table(B, n, T, d)
            dense = jnp.zeros((B, n * T, d), jnp.float32)
            upd = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
            lens = jnp.asarray([S, max(S - 1, 1)], jnp.int32)
            index = jnp.full((B,), idx, jnp.int32)
            got = L.paged_gather(
                L.paged_cache_update(pool, upd, table, index, lens), table)
            want = L.cache_update(dense, upd, index, lens)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_null_page_rows_drop_writes(self):
        """A dead row (all-null table) must not scribble on the pool —
        its writes are routed out of bounds and dropped."""
        B, n, T, d = 2, 2, 4, 4
        pool, table = self._pool_and_table(B, n, T, d)
        table = table.at[1].set(NULL_PAGE)   # row 1 is dead
        upd = jnp.ones((B, T, d), jnp.float32)
        new = L.paged_cache_update(pool, upd, table,
                                   jnp.zeros((B,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(new[0]),
                                      np.zeros((T, d)))  # null page clean
        assert float(jnp.abs(new[int(table[0, 0])]).sum()) > 0

    @pytest.mark.parametrize("kv_len", [7, 8, 9, 16, 24, 31, 32])
    def test_mask_parity_at_page_boundaries(self, kv_len):
        """paged_attention_mask == dense attention_mask when every page is
        real, at lengths straddling each page boundary (the parity
        contract the engine's bit-identical token streams rest on)."""
        B, n, T, Sq = 2, 4, 8, 1
        Sk = n * T
        table = jnp.arange(1, B * n + 1, dtype=jnp.int32).reshape(B, n)
        off = jnp.asarray([kv_len - 1, max(kv_len - 2, 0)], jnp.int32)
        kl = off + Sq
        dense = L.attention_mask(Sq, Sk, causal=True, q_offset=off,
                                 kv_len=kl)
        paged = L.paged_attention_mask(Sq, Sk, table, causal=True,
                                       q_offset=off, kv_len=kl)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))

    def test_mask_blocks_null_pages(self):
        """With a partially-null table the paged mask must block exactly
        the positions belonging to null pages."""
        n, T, Sq = 4, 8, 1
        Sk = n * T
        table = jnp.asarray([[1, 2, NULL_PAGE, NULL_PAGE]], jnp.int32)
        m = L.paged_attention_mask(Sq, Sk, table, causal=True,
                                   q_offset=jnp.asarray([Sk - 1]),
                                   kv_len=jnp.asarray([Sk]))
        got = np.asarray(m)[0, 0]
        np.testing.assert_array_equal(got[:2 * T], True)
        np.testing.assert_array_equal(got[2 * T:], False)

    def test_copy_pool_pages_skips_table(self):
        pool = {"k_pages": jnp.arange(24, dtype=jnp.float32
                                      ).reshape(2, 3, 2, 2),
                "table": jnp.ones((2, 1, 3), jnp.int32)}
        out = L.copy_pool_pages(pool, jnp.asarray([1]), jnp.asarray([2]))
        np.testing.assert_array_equal(np.asarray(out["k_pages"][:, 2]),
                                      np.asarray(pool["k_pages"][:, 1]))
        np.testing.assert_array_equal(np.asarray(out["table"]),
                                      np.asarray(pool["table"]))


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _mkreqs(vocab=97, cfg: ModelConfig | None = None):
    rng = np.random.default_rng(42)
    shared = rng.integers(0, vocab, 20)
    out = []
    for i in range(6):
        if i % 2 == 0:
            p = np.concatenate([shared, rng.integers(0, vocab, 5 + i)])
        else:
            p = rng.integers(0, vocab, 10 + i)
        out.append(Request(uid=i, prompt=p.astype(np.int32),
                           max_new_tokens=6, extras=_extras(cfg, i)))
    return out


def _serve(cfg, reqs, **kw):
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64,
                        chunk_tokens=16, **kw)
    for r in reqs:
        eng.submit(r)
    return {r.uid: r.tokens.tolist() for r in eng.run_until_empty()}, eng


class TestPagedEngine:
    @pytest.mark.parametrize("kind", ["dense", "moe", "mla_moe",
                                      "encdec", "vlm"])
    def test_bit_parity_with_dense_layout(self, kind):
        """Token streams are bit-identical between the dense and paged
        layouts for every paged family (SSM exempt by construction).
        Admit families page only decoder self-attention KV — the
        admission leaves (cross-KV, src_len, pos_off) stay dense — and
        opt out of the token-keyed prefix registry (their cache rows
        depend on modality input, so sharing would be unsound): despite
        the shared 20-token prompt prefix, no prefix hit may fire."""
        cfg = tiny_cfg(kind=kind)
        dense, _ = _serve(cfg, _mkreqs(cfg=cfg))
        paged, eng = _serve(cfg, _mkreqs(cfg=cfg), kv_layout="paged",
                            page_size=8)
        assert dense == paged
        rep = eng.report()
        assert rep["paging"]["pages_in_use"] >= 0
        assert rep["paging"]["peak_in_use"] > 0
        if kind in ("encdec", "vlm"):
            assert rep["paging"]["prefix_hits"] == 0

    def test_prefix_reuse_skips_prefill_and_keeps_parity(self):
        """A later request sharing a completed request's prefix maps the
        registered pages (prefix hit, fewer prefill chunks) and still
        produces the exact dense-layout token stream."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        shared = prompt(7, 32)
        tail_a = prompt(8, 8)
        tail_b = prompt(9, 8)
        reqs = [Request(uid=0, prompt=np.concatenate([shared, tail_a]),
                        max_new_tokens=4),
                Request(uid=1, prompt=np.concatenate([shared, tail_b]),
                        max_new_tokens=4)]

        def run(**kw):
            eng = ServingEngine(model, params, cfg, max_batch=2,
                                max_len=64, chunk_tokens=16, **kw)
            out = {}
            for r in reqs:                  # sequential: uid 0 registers
                eng.submit(Request(uid=r.uid, prompt=r.prompt.copy(),
                                   max_new_tokens=r.max_new_tokens))
                out.update({x.uid: x.tokens.tolist()
                            for x in eng.run_until_empty()})
            return out, eng

        dense, _ = run()
        paged, eng = run(kv_layout="paged", page_size=8)
        assert dense == paged
        rep = eng.report()["paging"]
        assert rep["prefix_hits"] >= 1
        assert rep["prefix_hit_tokens"] >= 32
        assert eng._stats["chunk_steps"] < 6  # uid 1 skipped shared chunks

    def test_exhaustion_under_full_lane_defers_admission(self):
        """Free-list exhaustion with the lane full: later requests wait at
        the queue head for a retirement instead of failing, and every
        request still completes (deadlock-free admission)."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        # 9 usable pages; each request reserves ceil((12+4)/8) = 2 pages,
        # so at most 4 of the 6 requests fit in flight at once
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=32,
                            chunk_tokens=16, kv_layout="paged", page_size=8,
                            num_pages=10, prefix_cache=False)
        for i in range(6):
            eng.submit(Request(uid=i, prompt=prompt(20 + i, 12),
                               max_new_tokens=4))
        res = eng.run_until_empty()
        assert sorted(r.uid for r in res) == list(range(6))
        assert all(r.n_tokens == 4 for r in res)
        rep = eng.report()["paging"]
        assert rep["peak_in_use"] <= 9
        assert rep["pages_in_use"] == 0      # every page returned

    def test_exhaustion_with_nothing_in_flight_is_loud(self):
        """A request whose reservation can never be satisfied must raise
        PageCacheFull, not deadlock the admission loop."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=32,
                            chunk_tokens=16, kv_layout="paged", page_size=8,
                            num_pages=3)     # 2 usable < ceil(28/8) = 4
        eng.submit(Request(uid=0, prompt=prompt(0, 24), max_new_tokens=4))
        with pytest.raises(PageCacheFull):
            eng.run_until_empty()

    def test_mid_decode_retire_releases_only_own_refs(self):
        """Refcount drop on mid-decode retire, end to end: two readers of
        a shared prefix with different budgets; the early retirement frees
        only its private pages, and after the drain every page is either
        free or held by the registry alone."""
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64,
                            chunk_tokens=16, kv_layout="paged", page_size=8)
        shared = prompt(5, 24)
        eng.submit(Request(uid=0, prompt=np.concatenate([shared,
                                                         prompt(6, 4)]),
                           max_new_tokens=2))
        eng.run_until_empty()               # uid 0 registers the prefix
        eng.submit(Request(uid=1, prompt=np.concatenate([shared,
                                                         prompt(7, 4)]),
                           max_new_tokens=2))
        eng.submit(Request(uid=2, prompt=np.concatenate([shared,
                                                         prompt(8, 4)]),
                           max_new_tokens=12))
        res = eng.run_until_empty()
        assert {r.uid: r.n_tokens for r in res} == {1: 2, 2: 12}
        alloc = eng._allocator
        rep = eng.report()["paging"]
        assert rep["prefix_hits"] >= 2
        # all live references now belong to the registry
        assert rep["pages_in_use"] == rep["registry_entries"]
        assert int(alloc.refs.max()) == 1    # no leaked reader refs

    def test_layout_validation(self):
        cfg = tiny_cfg()
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        with pytest.raises(ValueError, match="kv_layout"):
            ServingEngine(model, params, cfg, max_batch=2, max_len=32,
                          kv_layout="interleaved")
        with pytest.raises(ValueError, match="page_size"):
            ServingEngine(model, params, cfg, max_batch=2, max_len=36,
                          kv_layout="paged", page_size=8)
        ssm_cfg = tiny_cfg(kind="mamba1", ssm_state=8)
        ssm = get_model(ssm_cfg)
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(ssm, ssm.init(jax.random.key(0), ssm_cfg),
                          ssm_cfg, max_batch=2, max_len=32,
                          kv_layout="paged", page_size=8)
