"""End-to-end tests of the paper's pipeline: profile -> fit -> predict -> tune."""

import numpy as np
import pytest

from repro.core.autotuner import BASELINE, GemmAutotuner
from repro.core.features import NUMERIC_FEATURES, TARGETS, config_features
from repro.core.hwsim import GemmConfig, TpuGemmSimulator
from repro.core.mlperf import train_test_split
from repro.core.predictor import PerfPredictor
from repro.core.profiler import (
    collect_dataset,
    feature_table,
    load_dataset,
    save_dataset,
    sweep_configs,
)


@pytest.fixture(scope="module")
def dataset():
    # 2,200 configs: enough for R^2 > 0.95 while keeping module setup and
    # the RF fits fast (the batched substrate collects this in ~25 ms).
    return collect_dataset(n_configs=2200, seed=0)


@pytest.fixture(scope="module")
def fitted(dataset):
    tr, te = train_test_split(dataset, test_size=0.2, random_state=0)
    pred = PerfPredictor(model="rf", residual=True, fast=True).fit(tr)
    return pred, tr, te


class TestProfiler:
    def test_sweep_size_and_variety(self):
        cfgs = sweep_configs(n_configs=500, seed=1)
        assert len(cfgs) == 500
        assert len({c.layout for c in cfgs}) == 4
        assert len({c.dtype for c in cfgs}) == 2
        assert len({(c.block_m, c.block_n, c.block_k) for c in cfgs}) > 10

    def test_profile_table_columns(self, dataset):
        for col in NUMERIC_FEATURES + TARGETS:
            assert col in dataset, col
        n = len(dataset["runtime_ms"])
        assert n > 2000
        assert np.isfinite(dataset["runtime_ms"]).all()
        assert (dataset["power_w"] > 0).all()

    def test_dataset_roundtrip(self, dataset, tmp_path):
        p = str(tmp_path / "d.npz")
        save_dataset(dataset, p)
        back = load_dataset(p)
        np.testing.assert_allclose(back["runtime_ms"], dataset["runtime_ms"])

    def test_feature_table_projection(self, dataset):
        ft = feature_table(dataset)
        assert set(ft) == set(NUMERIC_FEATURES)

    def test_config_features_consistency(self):
        cfg = GemmConfig(1024, 2048, 512, 128, 256, 512)
        f = config_features(cfg)
        assert f["total_flops"] == 2 * 1024 * 2048 * 512
        assert f["mxnxk"] == 1024 * 2048 * 512
        assert f["grid_steps"] == (1024 // 128) * (2048 // 256) * (512 // 512)


class TestPredictor:
    def test_runtime_r2_high(self, fitted):
        pred, tr, te = fitted
        rep = pred.evaluate(te)
        # Paper: runtime R^2 = 0.98. Demand >0.95 from the fast test model.
        assert rep["runtime_ms"]["r2"] > 0.95, rep["runtime_ms"]

    def test_all_targets_predicted(self, fitted):
        pred, tr, te = fitted
        out = pred.predict(te)
        assert set(out) == set(TARGETS)
        assert (out["runtime_ms"] > 0).all()

    @pytest.mark.slow
    def test_beats_linreg(self, fitted, dataset):
        pred, tr, te = fitted
        lin = PerfPredictor(model="linreg").fit(tr)
        from repro.core.mlperf import r2_score

        truth = np.stack([te[t] for t in TARGETS], axis=1)
        r2_rf = r2_score(truth[:, 0], pred.predict_matrix(te)[:, 0])
        r2_lin = r2_score(truth[:, 0], lin.predict_matrix(te)[:, 0])
        assert r2_rf > r2_lin + 0.05

    def test_jax_forest_traversal_exact(self, fitted):
        """Given identical scaled inputs, jitted traversal == numpy."""
        pred, tr, te = fitted
        import jax.numpy as jnp
        from repro.core.mlperf.jaxpredict import JaxForestPredictor

        X = np.stack([te[k] for k in pred.feature_names], axis=1)[:64]
        Xs = pred.scaler.transform(X)
        want = pred.model.predict(Xs)
        got = np.asarray(JaxForestPredictor(pred.model)(jnp.asarray(Xs,
                                                                    jnp.float32)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.slow
    def test_jax_predictor_close_in_distribution(self, fitted):
        """fp32 feature scaling can flip exact-threshold splits; demand
        functional closeness (median <1% error, p90 <10%)."""
        pred, tr, te = fitted
        import jax.numpy as jnp

        fn = pred.jax_predictor()
        X = np.stack([te[k] for k in pred.feature_names], axis=1)[:256]
        got = np.asarray(fn(jnp.asarray(X, jnp.float32)))
        want = pred.predict_matrix({k: te[k][:256] for k in te})
        rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-9)
        assert np.median(rel) < 0.01
        assert np.quantile(rel, 0.90) < 0.10

    def test_save_load_roundtrip(self, fitted, tmp_path):
        pred, tr, te = fitted
        p = str(tmp_path / "pred.npz")
        pred.save(p)
        back = PerfPredictor.load(p)
        np.testing.assert_allclose(back.predict_matrix(te),
                                   pred.predict_matrix(te))


class TestAutotuner:
    @pytest.fixture(scope="class")
    def tuner(self, fitted):
        pred, tr, te = fitted
        return GemmAutotuner(pred, TpuGemmSimulator(seed=3))

    def test_candidates_are_valid(self, tuner):
        cfgs = tuner.candidate_configs(4096, 4096, 4096)
        assert len(cfgs) > 20
        for c in cfgs[:10]:
            assert tuner.sim.analyze(c).valid

    def test_tuned_beats_baseline_runtime(self, tuner):
        rep = tuner.tune_report(4096, 4096, 4096)
        assert rep["speedup"] > 1.2, rep

    def test_energy_objective_cuts_energy(self, tuner):
        rep = tuner.tune_report(4096, 4096, 4096, objective="energy")
        assert rep["energy_reduction_pct"] > 0, rep

    def test_cache_hit_returns_same(self, tuner):
        a = tuner.best_config(2048, 2048, 2048)
        b = tuner.best_config(2048, 2048, 2048)
        assert a == b
        assert "2048,2048,2048,bf16,runtime" in tuner._cache

    def test_small_gemm_does_not_blow_up(self, tuner):
        cfg = tuner.best_config(64, 128, 256)
        assert cfg.block_m <= 128 or cfg.block_m == BASELINE.block_m

    def test_decode_shape_gemv(self, tuner):
        """Skinny decode-style GEMM (m=16) must tune without error."""
        rep = tuner.tune_report(16, 4096, 4096)
        assert rep["speedup"] >= 0.9
