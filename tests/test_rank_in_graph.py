"""Tests for the fully in-graph candidate ranker (`rank_in_graph`).

The acceptance contract: the in-graph path (jnp feature grid + compiled
predictor + in-jit top-k, scoped x64) returns the same winners as the
trace-time `rank()` over a >=512-candidate sweep, reuses one compiled
ranker across GEMM shapes (no retrace — extents are traced values), and
plugs into `tune_many`/`warm_gemm_cache` as a drop-in ranking mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotuner import GemmAutotuner
from repro.core.hwsim import TpuGemmSimulator
from repro.core.predictor import PerfPredictor
from repro.core.profiler import collect_dataset

# four shapes x the 160-block static grid = 640 candidates >= the
# 512-candidate acceptance sweep
SHAPES = [(1024, 1024, 1024), (16, 2048, 2048), (4096, 4096, 1024),
          (333, 777, 1234)]


@pytest.fixture(scope="module")
def rf_pred():
    table = collect_dataset(n_configs=600, seed=0, chip="tpu_v5e")
    return PerfPredictor(model="rf", residual=True, fast=True,
                         chip="tpu_v5e").fit(table)


@pytest.fixture()
def tuner(rf_pred):
    return GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3), scorer="jit")


class TestWinnerParity:
    def test_same_winners_as_trace_rank_512(self, tuner):
        """>=512 candidates across the fleet: per shape, the in-graph
        top-k must equal the trace-time rank()'s head, and the in-graph
        scores must match the trace-time jit scores bit-for-bit."""
        tops, scores = tuner.rank_in_graph(SHAPES, top_k=3)
        total = 0
        for (m, n, k), top, sc in zip(SHAPES, tops, scores):
            cfgs, X = tuner.candidate_table(m, n, k, "bf16")
            total += len(cfgs)
            order = tuner.rank(cfgs, features=X)
            for j, cfg in enumerate(top):
                want = cfgs[order[j]]
                assert (cfg.block_m, cfg.block_n, cfg.block_k) == (
                    want.block_m, want.block_n, want.block_k), (m, n, k, j)
            trace_scores = tuner._scores_from_matrix(
                tuner._predict_features(X), "runtime")
            np.testing.assert_array_equal(
                sc[:len(top)], trace_scores[order[:len(top)]])
        assert total >= 512

    @pytest.mark.parametrize("objective", ["energy", "edp", "power"])
    def test_objectives_match_trace_rank(self, tuner, objective):
        tops, _ = tuner.rank_in_graph(SHAPES[:2], objective=objective,
                                      top_k=1)
        for (m, n, k), top in zip(SHAPES[:2], tops):
            cfgs, X = tuner.candidate_table(m, n, k, "bf16")
            best = cfgs[tuner.rank(cfgs, objective=objective,
                                   features=X)[0]]
            assert (top[0].block_m, top[0].block_n, top[0].block_k) == (
                best.block_m, best.block_n, best.block_k)

    def test_f32_mode_ranks_plausibly(self, tuner):
        """The approximate f32 mode must produce valid configs whose
        predicted runtime is near-optimal under the exact scorer (branch
        flips may reorder near-ties, not wreck the ranking)."""
        tops, _ = tuner.rank_in_graph(SHAPES[:1], top_k=1, x64=False)
        (m, n, k), top = SHAPES[0], tops[0]
        assert top, "f32 mode returned no candidates"
        cfgs, X = tuner.candidate_table(m, n, k, "bf16")
        scores = tuner._scores_from_matrix(tuner._predict_features(X),
                                           "runtime")
        key = (top[0].block_m, top[0].block_n, top[0].block_k)
        got = next(scores[i] for i, c in enumerate(cfgs)
                   if (c.block_m, c.block_n, c.block_k) == key)
        assert got <= np.quantile(scores, 0.05) * 1.5


class TestNoRetrace:
    def test_one_trace_serves_many_shape_fleets(self, tuner):
        assert tuner.graph_traces == 0
        tuner.rank_in_graph(SHAPES, top_k=3)
        assert tuner.graph_traces == 1
        # different extents, same fleet-size bucket: no retrace
        tuner.rank_in_graph([(2048, 2048, 2048), (64, 512, 4096),
                             (100, 200, 300), (512, 512, 512)], top_k=3)
        assert tuner.graph_traces == 1
        # fleet sizes share power-of-two buckets (padded), so a smaller
        # fleet in the same bucket also reuses the trace
        tuner.rank_in_graph([(96, 96, 96)], top_k=3)
        traces_small = tuner.graph_traces
        tuner.rank_in_graph([(97, 97, 97)], top_k=3)
        assert tuner.graph_traces == traces_small

    def test_validity_masked_in_graph(self, tuner):
        """Every returned candidate is simulator-valid and clip-legal —
        the static grid is pruned by the in-graph mask, not in Python."""
        tops, _ = tuner.rank_in_graph([(8, 128, 128)], top_k=8)
        assert tops[0], "no valid candidates for a tiny GEMM?"
        valid = tuner.sim.analyze_batch(tops[0])["valid"]
        assert valid.all()
        legal = {(c.block_m, c.block_n, c.block_k)
                 for c in tuner.candidate_configs(8, 128, 128)}
        for cfg in tops[0]:
            assert (cfg.block_m, cfg.block_n, cfg.block_k) in legal


class TestUnderOuterTrace:
    """The production call path: `ops.matmul` tunes at trace time, so
    `rank_in_graph` runs while an *outer* jit trace is live. Its inputs
    are trace-constants (static shapes), so the internal jitted ranker
    must dispatch eagerly on the default backend and hand back concrete
    winners — never outer-trace tracers."""

    def test_winner_parity_inside_live_trace(self, tuner):
        eager_tops, eager_scores = tuner.rank_in_graph(SHAPES, top_k=1)
        captured = {}

        @jax.jit
        def outer(x):
            tops, scores = tuner.rank_in_graph(SHAPES, top_k=1)
            captured["tops"] = tops
            captured["scores"] = scores
            return x + 1.0

        outer(jnp.zeros(2)).block_until_ready()
        assert captured, "ranker never ran under the outer trace"
        for (m, n, k), etop, ttop in zip(SHAPES, eager_tops,
                                         captured["tops"]):
            assert not isinstance(ttop[0].block_m, jax.core.Tracer)
            assert (etop[0].block_m, etop[0].block_n, etop[0].block_k) \
                == (ttop[0].block_m, ttop[0].block_n, ttop[0].block_k), \
                (m, n, k)
        for esc, tsc in zip(eager_scores, captured["scores"]):
            np.testing.assert_array_equal(np.asarray(esc[:1]),
                                          np.asarray(tsc[:1]))

    def test_warm_gemm_cache_graph_mode_under_trace(self, rf_pred):
        from repro.core import autotuner as at
        from repro.kernels import ops

        at.set_tuner(GemmAutotuner(rf_pred, TpuGemmSimulator(seed=0),
                                   scorer="jit"))
        ops._tuned_config.cache_clear()
        try:
            shapes = [(256, 512, 1024), (128, 256, 512)]
            eager = ops.warm_gemm_cache(shapes, dtype="bfloat16",
                                        rank_mode="graph")
            assert set(eager) == set(shapes)
            captured = {}

            @jax.jit
            def outer(x):
                captured.update(ops.warm_gemm_cache(
                    shapes, dtype="bfloat16", rank_mode="graph"))
                return x * 2.0

            outer(jnp.ones(2)).block_until_ready()
            assert captured == eager
        finally:
            at.set_tuner(None)
            ops._tuned_config.cache_clear()


class TestTuneManyModes:
    def test_graph_and_trace_tune_same_winners(self, rf_pred):
        t_graph = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                                scorer="jit")
        t_trace = GemmAutotuner(rf_pred, TpuGemmSimulator(seed=3),
                                scorer="jit")
        wg = t_graph.tune_many(SHAPES, rank_mode="graph")
        wt = t_trace.tune_many(SHAPES, rank_mode="trace")
        assert wg == wt

    def test_bad_rank_mode_rejected(self, tuner):
        with pytest.raises(ValueError, match="rank_mode"):
            tuner.tune_many(SHAPES[:1], rank_mode="psychic")

    def test_warm_gemm_cache_graph_mode(self, rf_pred):
        from repro.core import autotuner as at
        from repro.kernels import ops

        at.set_tuner(GemmAutotuner(rf_pred, TpuGemmSimulator(seed=0),
                                   scorer="jit"))
        ops._tuned_config.cache_clear()
        try:
            shapes = [(256, 512, 1024), (128, 256, 512)]
            out = ops.warm_gemm_cache(shapes, dtype="bfloat16",
                                      rank_mode="graph")
            assert set(out) == set(shapes)
            for (m, n, k), cfg in out.items():
                assert ops._tuned_config(
                    m, n, k, "bfloat16", "runtime", "tpu_v5e") == cfg
        finally:
            at.set_tuner(None)
            ops._tuned_config.cache_clear()
