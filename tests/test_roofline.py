"""Tests for roofline term derivation and HLO collective parsing."""

import pytest

from repro.core.chips import TPU_V5E
from repro.core.energy import energy_report, step_power_w
from repro.core.roofline import (
    RooflineReport,
    format_report_table,
    parse_collectives,
    roofline_from_artifacts,
)

HLO = """
HloModule jit_step, entry_computation_layout={...}

ENTRY %main (p0: f32[1024,512]) -> f32[1024,512] {
  %p0 = f32[1024,512]{1,0} parameter(0)
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[2048,512]{1,0} all-gather(bf16[1024,512]{1,0} %p0.c), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[256,512]{1,0} reduce-scatter(f32[1024,512]{1,0} %all-reduce.1), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[1024,512]{1,0} collective-permute(f32[1024,512]{1,0} %p0), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[1024,512]{1,0} all-to-all(f32[1024,512]{1,0} %p0), replica_groups={{0,1,2,3}}
  ROOT %r = f32[1024,512]{1,0} add(f32[1024,512]{1,0} %all-reduce.1, f32[1024,512]{1,0} %cp)
}
"""


class TestCollectiveParsing:
    def test_counts(self):
        st = parse_collectives(HLO, n_chips=8)
        assert st.counts["all-reduce"] == 1
        assert st.counts["all-gather"] == 1
        assert st.counts["reduce-scatter"] == 1
        assert st.counts["collective-permute"] == 1
        assert st.counts["all-to-all"] == 1

    def test_all_reduce_ring_bytes(self):
        st = parse_collectives(HLO, n_chips=8)
        operand = 1024 * 512 * 4
        assert st.operand_bytes["all-reduce"] == operand
        assert st.wire_bytes["all-reduce"] == pytest.approx(2 * operand * 3 / 4)

    def test_all_gather_uses_group_dims(self):
        st = parse_collectives(HLO, n_chips=8)
        operand = 1024 * 512 * 2  # bf16
        # replica_groups=[2,4] -> group size 4 -> (g-1) x operand
        assert st.wire_bytes["all-gather"] == pytest.approx(operand * 3)

    def test_permute_moves_operand_once(self):
        st = parse_collectives(HLO, n_chips=8)
        assert st.wire_bytes["collective-permute"] == 1024 * 512 * 4

    def test_no_collectives(self):
        st = parse_collectives("ENTRY %m { ROOT %x = f32[2]{0} add(...) }", 4)
        assert st.total_wire_bytes == 0


class TestRooflineReport:
    def _report(self):
        cost = {"flops": 1e12, "bytes accessed": 1e9}
        return roofline_from_artifacts(
            name="test", cost=cost, hlo_text=HLO, n_chips=256,
            model_flops=0.8e12 * 256, dtype="bf16",
        )

    def test_terms_formulae(self):
        r = self._report()
        assert r.compute_s == pytest.approx(1e12 / TPU_V5E.peak("bf16"))
        assert r.memory_s == pytest.approx(1e9 / TPU_V5E.hbm_bw)
        assert r.collective_s > 0

    def test_dominant_and_bound(self):
        r = self._report()
        assert r.dominant in ("compute", "memory", "collective")
        assert r.bound_s == max(r.compute_s, r.memory_s, r.collective_s)
        assert r.serial_s == pytest.approx(r.compute_s + r.memory_s + r.collective_s)

    def test_useful_fraction(self):
        r = self._report()
        assert r.useful_flops_fraction == pytest.approx(0.8)

    def test_roofline_fraction_bounded(self):
        r = self._report()
        assert 0 < r.roofline_fraction <= 1.0 + 1e-9

    def test_table_format(self):
        r = self._report()
        txt = format_report_table([r])
        assert "test" in txt and "dominant" in txt

    def test_bytes_accessed_fallback_keys(self):
        cost = {"flops": 1e12, "bytes accessed operand 0 {}": 5e8,
                "bytes accessed output {}": 5e8}
        r = roofline_from_artifacts(name="x", cost=cost, hlo_text="", n_chips=1)
        assert r.memory_s == pytest.approx(1e9 / TPU_V5E.hbm_bw)


class TestEnergyModel:
    def _r(self, c=1e-3, m=5e-4, coll=2e-4):
        return RooflineReport(
            name="e", n_chips=256, dtype="bf16", hlo_flops=1, hlo_bytes=1,
            collective_wire_bytes=1, compute_s=c, memory_s=m, collective_s=coll,
            model_flops=1,
        )

    def test_power_range(self):
        p = step_power_w(self._r())
        assert TPU_V5E.idle_power_w < p <= TPU_V5E.tdp_w

    def test_compute_bound_draws_more_than_idleish(self):
        busy = step_power_w(self._r(c=1e-3, m=1e-3, coll=1e-3))
        light = step_power_w(self._r(c=1e-3, m=1e-5, coll=1e-5))
        assert busy > light

    def test_energy_report_scaling(self):
        er = energy_report(self._r(), tokens_per_step=1e6)
        assert er.system_power_w == pytest.approx(er.chip_power_w * 256)
        assert er.energy_per_token_j == pytest.approx(
            er.energy_per_step_j / 1e6)
        assert er.edp == pytest.approx(er.energy_per_step_j * er.step_s)
