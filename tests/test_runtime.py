"""Distributed-runtime tests: optimizer, checkpoint/restart, fault tolerance,
data pipeline determinism, serving engine, gradient compression."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import (
    DataConfig,
    DataLoader,
    SyntheticLMDataset,
)
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state, lr_at
from repro.serving.engine import Request, ServingEngine
from repro.train.ft import StragglerDetector, StepTimer
from repro.train.loop import LoopConfig, resume_or_init, run_train_loop
from repro.train.step import init_train_state, make_train_step


class TestAdamW:
    def _setup(self):
        params = {"w": jnp.ones((4, 4), jnp.float32),
                  "b": jnp.zeros((4,), jnp.float32)}
        grads = {"w": jnp.full((4, 4), 0.5), "b": jnp.ones((4,))}
        return params, grads, init_opt_state(params)

    def test_step_moves_params_against_grad(self):
        params, grads, opt = self._setup()
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
        new_params, new_opt, metrics = apply_updates(params, grads, opt, cfg)
        assert (np.asarray(new_params["w"]) < 1.0).all()
        assert int(new_opt["step"]) == 1
        assert metrics["grad_norm"] > 0

    def test_grad_clip(self):
        params, grads, opt = self._setup()
        grads = jax.tree.map(lambda g: g * 1e6, grads)
        cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0)
        _, _, metrics = apply_updates(params, grads, opt, cfg)
        assert float(metrics["clip_scale"]) < 1e-4

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
        lrs = [float(lr_at(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
        assert lrs[1] == pytest.approx(0.5)           # mid-warmup
        assert lrs[2] == pytest.approx(1.0, abs=0.01) # peak
        assert lrs[4] == pytest.approx(0.1, abs=0.01) # floor
        assert lrs[3] < lrs[2]

    def test_master_weights_fp32_with_bf16_params(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        opt = init_opt_state(params)
        assert opt["master"]["w"].dtype == jnp.float32
        grads = {"w": jnp.full((4, 4), 0.125, jnp.bfloat16)}
        new_params, new_opt, _ = apply_updates(
            params, grads, opt, AdamWConfig(warmup_steps=0))
        assert new_params["w"].dtype == jnp.bfloat16
        assert new_opt["master"]["w"].dtype == jnp.float32


class TestCheckpoint:
    def _state(self, x=1.0):
        return {"params": {"w": jnp.full((3, 3), x)},
                "opt": {"step": jnp.int32(7)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(10, self._state(2.5), data_state={"step": 10},
                 blocking=True)
        state, ds = mgr.restore()
        assert float(state["params"]["w"][0, 0]) == 2.5
        assert ds["step"] == 10
        assert mgr.latest_step() == 10

    def test_async_save_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._state(), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_gc_keeps_newest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in [1, 2, 3, 4]:
            mgr.save(s, self._state(float(s)), blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in [1, 2]:
            mgr.save(s, self._state(float(s)), blocking=True)
        state, _ = mgr.restore(step=1)
        assert float(state["params"]["w"][0, 0]) == 1.0

    def test_atomic_no_partial_on_missing(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            mgr.restore()


class TestFaultTolerance:
    def test_straggler_flagging(self):
        det = StragglerDetector(n_hosts=4)
        for step in range(10):
            for h in range(4):
                det.record(h, 1.0 if h != 2 else 3.0)
            flags = det.update_flags()
        assert flags == [2]

    def test_no_flags_when_uniform(self):
        det = StragglerDetector(n_hosts=4)
        for step in range(10):
            for h in range(4):
                det.record(h, 1.0 + 0.01 * h)
            flags = det.update_flags()
        assert flags == []

    def test_recovered_straggler_unflagged(self):
        det = StragglerDetector(n_hosts=2)
        for _ in range(6):
            det.record(0, 1.0)
            det.record(1, 5.0)
            det.update_flags()
        for _ in range(30):
            det.record(0, 1.0)
            det.record(1, 1.0)
            flags = det.update_flags()
        assert flags == []

    def test_step_timer_discards_warmup(self):
        t = StepTimer(warmup=1)
        for _ in range(3):
            t.start()
            t.stop()
        assert len(t.times) == 2


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        ds = SyntheticLMDataset(DataConfig(seq_len=16, global_batch=4,
                                           vocab=100, seed=3))
        a = ds.batch_at(5)
        b = ds.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_partitions_batch(self):
        ds = SyntheticLMDataset(DataConfig(seq_len=8, global_batch=8,
                                           vocab=50))
        h0 = ds.batch_at(0, host_id=0, n_hosts=2)
        h1 = ds.batch_at(0, host_id=1, n_hosts=2)
        assert h0["tokens"].shape == (4, 8)
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_loader_resume(self):
        ds = SyntheticLMDataset(DataConfig(seq_len=8, global_batch=2,
                                           vocab=50))
        l1 = DataLoader(ds)
        for _ in range(3):
            l1.next()
        ckpt = l1.checkpoint()
        b_next = l1.next()
        l2 = DataLoader(ds)
        l2.restore(ckpt)
        np.testing.assert_array_equal(l2.next()["tokens"], b_next["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLMDataset(DataConfig(seq_len=8, global_batch=2,
                                           vocab=50))
        b = ds.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestTrainLoopEndToEnd:
    def test_loss_decreases_and_resumes(self, tmp_path):
        cfg = get_config("qwen2-7b", smoke=True)
        model = get_model(cfg)
        ds = SyntheticLMDataset(DataConfig(seq_len=32, global_batch=4,
                                           vocab=cfg.vocab, seed=0))
        loader = DataLoader(ds)
        step_fn = jax.jit(make_train_step(
            model, cfg, AdamWConfig(lr=1e-3, warmup_steps=5,
                                    decay_steps=100)))
        ckpt = CheckpointManager(str(tmp_path))
        state = init_train_state(jax.random.key(0), model, cfg)
        state, summary = run_train_loop(
            train_step=step_fn, state=state, loader=loader, ckpt=ckpt,
            loop_cfg=LoopConfig(total_steps=30, ckpt_every=10, log_every=100),
            log_fn=lambda s: None, install_signal_handlers=False)
        curve = summary["loss_curve"]
        assert curve[-5:].mean() < curve[:5].mean(), "loss did not decrease"

        # restart from checkpoint: should resume at step 30
        loader2 = DataLoader(ds)
        state2, start = resume_or_init(
            ckpt=ckpt, init_fn=lambda: init_train_state(
                jax.random.key(0), model, cfg), loader=loader2)
        assert start == 30
        assert loader2.state.step == 30
        np.testing.assert_allclose(
            np.asarray(state2["params"]["ln_f"]["scale"], np.float32),
            np.asarray(state["params"]["ln_f"]["scale"], np.float32),
            rtol=1e-6)


class TestServingEngine:
    def test_greedy_generation_deterministic(self):
        cfg = get_config("qwen2-7b", smoke=True)
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64)
        rng = np.random.default_rng(0)
        for uid in range(3):
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, 8),
                               max_new_tokens=5))
        results = eng.run_until_empty()
        assert len(results) == 3
        assert all(len(r.tokens) == 5 for r in results)
        # same prompts again -> identical generations (greedy)
        eng2 = ServingEngine(model, params, cfg, max_batch=2, max_len=64)
        rng = np.random.default_rng(0)
        for uid in range(3):
            eng2.submit(Request(uid=uid,
                                prompt=rng.integers(0, cfg.vocab, 8),
                                max_new_tokens=5))
        results2 = eng2.run_until_empty()
        for a, b in zip(results, results2):
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_wave_packing_respects_max_batch(self):
        cfg = get_config("qwen2-7b", smoke=True)
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=32)
        for uid in range(5):
            eng.submit(Request(uid=uid, prompt=np.arange(4), max_new_tokens=2))
        first_wave = eng.run_wave()
        assert len(first_wave) == 2
        assert len(eng.queue) == 3


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        from repro.distributed.compress import _quantize

        x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                        jnp.float32)
        q, scale = _quantize(x, jax.random.key(0))
        err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
        assert err.max() <= float(scale) * 1.01  # within one quant step

    def test_wire_bytes_saved(self):
        from repro.distributed.compress import wire_bytes_saved

        grads = {"w": jnp.zeros((1000,))}
        assert wire_bytes_saved(grads, bits=8, from_bits=16) == 1000
