"""Serving-engine tests: termination bugfixes (EOS-as-first-token, budget
of one), padding parity, continuous-batching slot refill, wave-vs-continuous
token-stream equality, telemetry and per-request energy accounting."""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig, gemm_shape_counts, gemm_shapes
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="serve-test", kind="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, param_dtype="float32",
        activation_dtype="float32", remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def served():
    cfg = tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


def make_engine(served, **kw):
    cfg, model, params = served
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    return ServingEngine(model, params, cfg, **kw)


def prompt(seed: int, n: int, vocab: int = 256) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, vocab, n).astype(np.int32)


def greedy_tokens(served, p: np.ndarray, mode: str = "continuous",
                  **req_kw) -> np.ndarray:
    eng = make_engine(served, mode=mode)
    eng.submit(Request(uid=0, prompt=p.copy(), **req_kw))
    (res,) = eng.run_until_empty()
    return res.tokens


# ---------------------------------------------------------------------------
# termination bugfixes
# ---------------------------------------------------------------------------


class TestTermination:
    @pytest.mark.parametrize("mode", ["wave", "continuous"])
    def test_eos_as_first_token_stops_immediately(self, served, mode):
        """Regression for the wave loop appending the first sampled token
        with no done-check: an EOS emitted as the *first* token must end
        the request at one token, not run to full budget."""
        p = prompt(0, 8)
        first = int(greedy_tokens(served, p, max_new_tokens=8)[0])
        eng = make_engine(served, mode=mode)
        eng.submit(Request(uid=0, prompt=p.copy(), max_new_tokens=8,
                           eos_id=first))
        (res,) = eng.run_until_empty()
        assert res.n_tokens == 1
        assert res.tokens.tolist() == [first]
        assert res.steps == 0          # never occupied a decode step

    @pytest.mark.parametrize("mode", ["wave", "continuous"])
    def test_max_new_tokens_one(self, served, mode):
        eng = make_engine(served, mode=mode)
        eng.submit(Request(uid=0, prompt=prompt(1, 6), max_new_tokens=1))
        (res,) = eng.run_until_empty()
        assert res.n_tokens == 1 and len(res.tokens) == 1

    def test_mixed_budgets_in_one_batch(self, served):
        """Short-budget requests must stop at their own budget even when
        batched with longer ones — in both modes, with equal streams."""
        budgets = [2, 7, 3, 5]
        per_mode = {}
        for mode in ("wave", "continuous"):
            eng = make_engine(served, mode=mode)
            for uid, b in enumerate(budgets):
                eng.submit(Request(uid=uid, prompt=prompt(10 + uid, 5),
                                   max_new_tokens=b))
            per_mode[mode] = {r.uid: r for r in eng.run_until_empty()}
        for uid, b in enumerate(budgets):
            for mode in per_mode:
                assert per_mode[mode][uid].n_tokens == b
            np.testing.assert_array_equal(
                per_mode["wave"][uid].tokens,
                per_mode["continuous"][uid].tokens)

    def test_prompt_must_fit_max_len(self, served):
        eng = make_engine(served, max_len=16)
        with pytest.raises(ValueError):
            eng.submit(Request(uid=0, prompt=prompt(2, 16)))

    def test_budget_clamped_to_kv_room(self, served):
        """A budget larger than the remaining KV room is clamped, not
        allowed to scribble past max_len."""
        eng = make_engine(served, max_len=16, mode="continuous")
        eng.submit(Request(uid=0, prompt=prompt(3, 12), max_new_tokens=64))
        (res,) = eng.run_until_empty()
        assert res.n_tokens == 16 - 12


# ---------------------------------------------------------------------------
# steps vs n_tokens (energy denominator)
# ---------------------------------------------------------------------------


class TestStepsAccounting:
    def test_wave_steps_count_residency_not_tokens(self, served):
        """Old Result.steps reported len(tokens). A 2-token request riding
        a wave with an 8-token request stays resident for the whole wave:
        steps must reflect the executed decode iterations, n_tokens the
        generated count."""
        eng = make_engine(served, mode="wave")
        eng.submit(Request(uid=0, prompt=prompt(20, 4), max_new_tokens=2))
        eng.submit(Request(uid=1, prompt=prompt(21, 4), max_new_tokens=8))
        res = {r.uid: r for r in eng.run_until_empty()}
        assert res[0].n_tokens == 2 and res[1].n_tokens == 8
        # wave runs 7 decode iterations (first token comes from prefill)
        assert res[0].steps == res[1].steps == 7

    def test_continuous_steps_stop_at_retirement(self, served):
        eng = make_engine(served, mode="continuous")
        eng.submit(Request(uid=0, prompt=prompt(20, 4), max_new_tokens=2))
        eng.submit(Request(uid=1, prompt=prompt(21, 4), max_new_tokens=8))
        res = {r.uid: r for r in eng.run_until_empty()}
        assert res[0].n_tokens == 2 and res[0].steps == 1
        assert res[1].n_tokens == 8 and res[1].steps == 7


# ---------------------------------------------------------------------------
# padding parity
# ---------------------------------------------------------------------------


class TestPaddingParity:
    def test_short_prompt_alone_vs_padded_in_batch(self, served):
        """A short prompt served alone must produce the same greedy tokens
        as the same prompt padded into a batch with a much longer one —
        the prefill mask/length threading contract."""
        short, long_ = prompt(30, 5), prompt(31, 21)
        alone = greedy_tokens(served, short, max_new_tokens=8)
        for mode in ("wave", "continuous"):
            eng = make_engine(served, mode=mode)
            eng.submit(Request(uid=0, prompt=short.copy(),
                               max_new_tokens=8))
            eng.submit(Request(uid=1, prompt=long_.copy(),
                               max_new_tokens=8))
            res = {r.uid: r for r in eng.run_until_empty()}
            np.testing.assert_array_equal(res[0].tokens, alone, err_msg=mode)

    def test_slot_prefill_bucket_padding_is_invisible(self, served):
        """Bucketed right-padding (pow2 slot prefill) must not change
        generations: lengths just under and just over a bucket edge."""
        for n in (7, 8, 9):
            p = prompt(40 + n, n)
            a = greedy_tokens(served, p, mode="continuous",
                              max_new_tokens=6)
            b = greedy_tokens(served, p, mode="wave", max_new_tokens=6)
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def mixed_workload(n=9, seed=0, vocab=256):
    rng = np.random.default_rng(seed)
    return [
        (uid, rng.integers(0, vocab, rng.integers(4, 12)).astype(np.int32),
         int(rng.choice([4, 8, 16])))
        for uid in range(n)
    ]


class TestContinuousBatching:
    def _serve(self, served, mode, reqs, max_batch=3):
        eng = make_engine(served, mode=mode, max_batch=max_batch)
        for uid, p, mnt in reqs:
            eng.submit(Request(uid=uid, prompt=p.copy(),
                               max_new_tokens=mnt))
        return eng, {r.uid: r for r in eng.run_until_empty()}

    def test_slot_refill_mid_decode(self, served):
        """More requests than slots with mixed budgets: a request admitted
        mid-decode (after a short one retires) completes correctly."""
        reqs = mixed_workload()
        eng, res = self._serve(served, "continuous", reqs)
        assert set(res) == {uid for uid, _, _ in reqs}
        for uid, p, mnt in reqs:
            assert res[uid].n_tokens == mnt
            np.testing.assert_array_equal(
                res[uid].tokens,
                greedy_tokens(served, p, max_new_tokens=mnt))

    def test_streams_bit_identical_and_fewer_slot_steps(self, served):
        """Acceptance: identical greedy streams between modes, with
        measurably fewer executed decode-step*slots in continuous mode."""
        reqs = mixed_workload()
        ec, rc = self._serve(served, "continuous", reqs)
        ew, rw = self._serve(served, "wave", reqs)
        for uid in rw:
            np.testing.assert_array_equal(rc[uid].tokens, rw[uid].tokens)
        assert ec.report()["decode_steps"] < ew.report()["decode_steps"]
        assert ec.report()["slot_steps"] < ew.report()["slot_steps"]

    def test_single_slot_engine(self, served):
        reqs = mixed_workload(n=3, seed=5)
        _, res = self._serve(served, "continuous", reqs, max_batch=1)
        for uid, p, mnt in reqs:
            np.testing.assert_array_equal(
                res[uid].tokens,
                greedy_tokens(served, p, max_new_tokens=mnt))

    def test_small_max_len_engine(self, served):
        """max_len below the smallest pow2 bucket: the batch-axis probe
        and bucketing must use real (max_len-clamped) shapes."""
        eng = make_engine(served, max_len=6, mode="continuous")
        eng.submit(Request(uid=0, prompt=prompt(55, 3), max_new_tokens=3))
        eng.submit(Request(uid=1, prompt=prompt(56, 4), max_new_tokens=2))
        res = {r.uid: r for r in eng.run_until_empty()}
        assert res[0].n_tokens == 3 and res[1].n_tokens == 2
        np.testing.assert_array_equal(
            res[0].tokens,
            greedy_tokens(served, prompt(55, 3), max_new_tokens=3))

    def test_first_token_finisher_frees_slot_same_pass(self, served):
        """An admission that finishes on its first sampled token must not
        leave its slot dead for the next decode step when the queue still
        has work: the refill loop keeps admitting into the freed slot."""
        p_eos = prompt(57, 5)
        eos = int(greedy_tokens(served, p_eos, max_new_tokens=4)[0])
        eng = make_engine(served, mode="continuous", max_batch=2)
        eng.submit(Request(uid=0, prompt=prompt(58, 5), max_new_tokens=4))
        eng.submit(Request(uid=1, prompt=p_eos.copy(), max_new_tokens=4,
                           eos_id=eos))
        eng.submit(Request(uid=2, prompt=prompt(59, 5), max_new_tokens=4))
        res = {r.uid: r for r in eng.run_until_empty()}
        assert res[1].n_tokens == 1
        # uid2 takes uid1's slot in the same refill pass, so every decode
        # step runs with both slots live
        assert eng.report()["slot_occupancy"] == 1.0

    def test_auto_mode_picks_continuous_for_dense(self, served):
        eng = make_engine(served)
        assert eng._continuous_supported()
        eng.submit(Request(uid=0, prompt=prompt(50, 4), max_new_tokens=3))
        assert len(eng.run_until_empty()) == 1
        assert eng.report()["slot_occupancy"] > 0

    def test_wave_api_still_packs_max_batch(self, served):
        eng = make_engine(served)
        for uid in range(5):
            eng.submit(Request(uid=uid, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=2))
        first_wave = eng.run_wave()
        assert len(first_wave) == 2
        assert len(eng.queue) == 3

    @pytest.mark.parametrize("mode", ["wave", "continuous"])
    def test_nongreedy_ignores_dead_slots(self, served, mode):
        """A request's sampled stream must not depend on its neighbors:
        per-request RNG streams mean retiring a companion earlier (or
        serving alone) cannot shift the survivor's draws."""

        def sampled(companion_budget):
            eng = make_engine(served, greedy=False, seed=7, mode=mode)
            eng.submit(Request(uid=0, prompt=prompt(60, 6),
                               max_new_tokens=6))
            if companion_budget:
                eng.submit(Request(uid=1, prompt=prompt(61, 6),
                                   max_new_tokens=companion_budget))
            return {r.uid: r.tokens for r in eng.run_until_empty()}

        base = sampled(6)
        np.testing.assert_array_equal(base[0], sampled(6)[0])  # determinism
        # shorter-lived companion -> dead slot mid-serve; stream unchanged
        np.testing.assert_array_equal(base[0], sampled(2)[0])
        # no companion at all
        np.testing.assert_array_equal(base[0], sampled(0)[0])


class TestMoEFamilies:
    """The other CONTINUOUS_KINDS: continuous/wave bit-parity for MoE and
    MLA-MoE (capacity sized not to bind — the documented condition)."""

    def _cfg(self, kind):
        base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    vocab=128, param_dtype="float32",
                    activation_dtype="float32", remat=False,
                    capacity_factor=16.0, n_experts=4, top_k=2,
                    d_ff_expert=64)
        if kind == "moe":
            return tiny_cfg(kind="moe", d_ff=0, **base)
        return tiny_cfg(kind="mla_moe", d_ff=128, n_shared_experts=1,
                        kv_lora_rank=16, rope_head_dim=8, **base)

    @pytest.mark.parametrize("kind", ["moe", "mla_moe"])
    def test_continuous_matches_wave_with_slot_refill(self, kind):
        cfg = self._cfg(kind)
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        rng = np.random.default_rng(1)
        reqs = [(uid, rng.integers(0, cfg.vocab,
                                   rng.integers(4, 10)).astype(np.int32),
                 int(rng.choice([3, 6]))) for uid in range(4)]
        outs = {}
        for mode in ("continuous", "wave"):
            eng = ServingEngine(model, params, cfg, max_batch=2,
                                max_len=32, mode=mode)
            assert eng._continuous_supported()
            for uid, p, mnt in reqs:
                eng.submit(Request(uid=uid, prompt=p.copy(),
                                   max_new_tokens=mnt))
            outs[mode] = {r.uid: r for r in eng.run_until_empty()}
        for uid, _, mnt in reqs:
            assert outs["continuous"][uid].n_tokens == mnt
            np.testing.assert_array_equal(
                outs["continuous"][uid].tokens, outs["wave"][uid].tokens)

    def test_mla_counts_match_traced_projections(self):
        """MLA serves via its latent fleet (w_uq/w_dkv/w_kpe + cache-wide
        w_uk/w_uv), never the generic Q/K/V skeleton."""
        cfg = self._cfg("mla_moe")
        counts = gemm_shape_counts(cfg, 4, kv_rows=64)
        d, hd, pe = cfg.d_model, cfg.hd, cfg.rope_head_dim
        r = cfg.kv_lora_rank
        assert (4, cfg.n_heads * (hd + pe), d) in counts      # w_uq
        assert (4, r, d) in counts                            # w_dkv
        assert (4, pe, d) in counts                           # w_kpe
        assert counts[(64, cfg.n_heads * hd, r)] == \
            2 * cfg.n_layers                                  # w_uk/w_uv
        assert (4, cfg.kv_heads * hd, d) not in counts        # no K/V proj


class TestSsmFamilies:
    """mamba1/mamba2/hybrid are promoted out of the wave-mode fallback:
    chunked admission carries conv/scan state across chunk boundaries
    bit-exactly, so they serve continuously with wave-parity streams."""

    def _mamba(self):
        cfg = tiny_cfg(kind="mamba1", n_layers=2, d_ff=0, ssm_state=8,
                       expand=2, d_conv=4)
        model = get_model(cfg)
        return cfg, model, model.init(jax.random.key(0), cfg)

    def test_mamba_serves_continuously_with_wave_parity(self):
        cfg, model, params = self._mamba()
        reqs = [(uid, prompt(uid, 6, cfg.vocab), 4) for uid in range(3)]
        outs = {}
        for mode in ("continuous", "wave"):
            eng = ServingEngine(model, params, cfg, max_batch=2,
                                max_len=32, mode=mode)
            assert eng._continuous_supported()
            for uid, p, mnt in reqs:
                eng.submit(Request(uid=uid, prompt=p.copy(),
                                   max_new_tokens=mnt))
            outs[mode] = {r.uid: r for r in eng.run_until_empty()}
        for uid, _, mnt in reqs:
            assert outs["continuous"][uid].n_tokens == mnt
            np.testing.assert_array_equal(
                outs["continuous"][uid].tokens, outs["wave"][uid].tokens)

    @pytest.mark.parametrize("mode", ["wave", "continuous"])
    def test_attention_free_budget_not_clamped_by_max_len(self, mode):
        """SSM decode state is O(1) per token — no KV cache to run out
        of — so neither the prompt-length check nor the KV-room budget
        clamp applies to mamba1, even in a padded batch."""
        cfg, model, params = self._mamba()
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=32,
                            mode=mode)
        eng.submit(Request(uid=0, prompt=prompt(0, 28, cfg.vocab),
                           max_new_tokens=20))
        eng.submit(Request(uid=1, prompt=prompt(1, 6, cfg.vocab),
                           max_new_tokens=20))
        res = {r.uid: r for r in eng.run_until_empty()}
        assert res[0].n_tokens == 20
        assert res[1].n_tokens == 20

    def test_hybrid_budgets_clamp_per_row(self):
        """Hybrid now follows the right-padded `lengths` contract in both
        modes: each row's KV room is max_len - its *own* prompt length
        (the legacy left-pad shared-index clamp no longer applies)."""
        cfg = tiny_cfg(kind="hybrid", n_layers=2, d_ff=128, ssm_state=8,
                       expand=2, ssm_headdim=16, ssm_ngroups=1,
                       attn_every=2)
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        for mode in ("wave", "continuous"):
            eng = ServingEngine(model, params, cfg, max_batch=2,
                                max_len=32, mode=mode)
            assert eng._continuous_supported()
            eng.submit(Request(uid=0, prompt=prompt(0, 28, cfg.vocab),
                               max_new_tokens=20))
            eng.submit(Request(uid=1, prompt=prompt(1, 6, cfg.vocab),
                               max_new_tokens=20))
            res = {r.uid: r for r in eng.run_until_empty()}
            assert res[0].n_tokens == 32 - 28, mode
            assert res[1].n_tokens == 20, mode


# ---------------------------------------------------------------------------
# telemetry + energy accounting
# ---------------------------------------------------------------------------


class TestTelemetryAndEnergy:
    def test_result_telemetry_fields(self, served):
        eng = make_engine(served, mode="continuous")
        eng.submit(Request(uid=0, prompt=prompt(70, 6), max_new_tokens=5))
        (res,) = eng.run_until_empty()
        assert res.n_tokens == 5
        assert res.ttft_s >= res.queue_s >= 0
        assert res.tokens_per_s > 0
        assert res.energy_j > 0
        assert res.energy_per_token_j == pytest.approx(
            res.energy_j / res.n_tokens)

    def test_engine_report_fields(self, served):
        reqs = mixed_workload(n=6, seed=3)
        eng = make_engine(served, mode="continuous", max_batch=3)
        for uid, p, mnt in reqs:
            eng.submit(Request(uid=uid, prompt=p.copy(),
                               max_new_tokens=mnt))
        results = eng.run_until_empty()
        rep = eng.report()
        assert rep["requests"] == 6
        assert rep["generated_tokens"] == sum(r.n_tokens for r in results)
        assert 0 < rep["slot_occupancy"] <= 1
        assert rep["tokens_per_s"] > 0
        assert rep["j_per_token"] > 0
        # requests carry their attributed share; dead-slot decode spend is
        # charged to the engine so totals stay comparable with wave mode
        assert rep["attributed_energy_j"] == pytest.approx(
            sum(r.energy_j for r in results))
        assert rep["energy_j"] == pytest.approx(
            rep["attributed_energy_j"] + rep["idle_energy_j"])
        assert rep["idle_energy_j"] >= 0

    def test_continuous_beats_wave_on_j_per_token(self, served):
        """The Racing-to-Idle claim: on a mixed-budget workload the wave
        engine attributes strictly more energy per generated token."""
        reqs = mixed_workload()
        rep = {}
        for mode in ("continuous", "wave"):
            eng = make_engine(served, mode=mode, max_batch=3)
            for uid, p, mnt in reqs:
                eng.submit(Request(uid=uid, prompt=p.copy(),
                                   max_new_tokens=mnt))
            eng.run_until_empty()
            rep[mode] = eng.report()
        assert rep["continuous"]["j_per_token"] < rep["wave"]["j_per_token"]

    def test_chip_typo_raises_at_construction(self, served):
        """An unknown chip must fail loudly up front, not silently zero
        every energy estimate."""
        cfg, model, params = served
        with pytest.raises(ValueError):
            ServingEngine(model, params, cfg, chip="tpuv5e")

    def test_step_energy_estimates_scale_with_rows(self, served):
        from repro.core.energy import gemm_fleet_energy

        cfg, _, _ = served
        small = gemm_fleet_energy(gemm_shape_counts(cfg, 8),
                                  chip="tpu_v5e", dtype="bfloat16")
        big = gemm_fleet_energy(gemm_shape_counts(cfg, 4096),
                                chip="tpu_v5e", dtype="bfloat16")
        assert big.energy_j > small.energy_j > 0
        assert big.step_s > small.step_s > 0
        assert small.power_w <= big.power_w or small.power_w > 0

    def test_hybrid_counts_match_traced_in_proj(self):
        """The hybrid (mamba2/SSD) in_proj GEMM carries B/C state
        projections and the dt channel — the fleet must contain the shape
        the model actually traces, not mamba1's 2*d_inner."""
        # vocab != 2*d_inner, else the LM-head shape collides with the
        # mamba1-style in_proj this test asserts is absent
        cfg = tiny_cfg(kind="hybrid", n_layers=4, d_ff=128, ssm_state=8,
                       expand=2, ssm_headdim=16, ssm_ngroups=1,
                       attn_every=2, vocab=300)
        counts = gemm_shape_counts(cfg, 4)
        di = cfg.d_inner
        n_in = 2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state \
            + di // cfg.ssm_headdim
        assert (4, n_in, cfg.d_model) in counts
        assert (4, 2 * di, cfg.d_model) not in counts

    def test_gemm_shape_counts_consistent_with_shapes(self, served):
        cfg, _, _ = served
        counts = gemm_shape_counts(cfg, 16)
        assert sorted(counts) == gemm_shapes(cfg, 16)
        # one decode step: Q, 2x KV, O per layer; up+gate, down per layer;
        # one LM head
        d, hd = cfg.d_model, cfg.hd
        assert counts[(16, cfg.vocab, d)] == 1
        assert counts[(16, cfg.kv_heads * hd, d)] == 2 * cfg.n_layers
        assert counts[(16, cfg.d_ff, d)] == 2 * cfg.n_layers

    def test_serving_fleet_covers_slot_prefill_buckets(self, served):
        from repro.kernels import ops

        cfg, _, _ = served
        fleet = set(ops.serving_gemm_fleet(cfg, max_batch=4, max_len=64))
        assert set(gemm_shapes(cfg, 4)) <= fleet          # decode
        # batched prefill: head GEMM sized to rows actually unembedded
        assert set(gemm_shape_counts(cfg, 4 * 64, head_tokens=4)) <= fleet
        for b in (8, 16, 32, 64):                         # slot buckets
            assert set(gemm_shape_counts(cfg, b, head_tokens=1)) <= fleet
        # prefill never unembeds every position, so the full-row head
        # shape must NOT be pre-tuned (it is never traced)
        assert (4 * 64, cfg.vocab, cfg.d_model) not in fleet
        no_slots = set(ops.serving_gemm_fleet(
            cfg, max_batch=4, max_len=64, include_slot_prefill=False))
        assert not (set(gemm_shape_counts(cfg, 8, head_tokens=1))
                    <= no_slots)
