"""Sharding rules + distributed lowering tests.

These run in a subprocess with 16 virtual host devices so the main test
process keeps its single-device view (per the task spec, only the dry-run
may force a device count).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 16) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestParamRules:
    def test_rules_and_divisibility(self):
        stdout = _run_sub("""
            import jax, json
            from jax.sharding import PartitionSpec as P
            from repro.distributed.sharding import param_pspecs, sanitize_spec
            from repro.models.registry import get_model
            from repro.configs import get_config

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = get_config("qwen2-7b", smoke=True)
            model = get_model(cfg)
            shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                                    jax.random.key(0))
            specs = param_pspecs(shapes, mesh, fsdp=True)
            # attention wq: (L, d, H*hd) -> (None, data, model)
            wq = specs["blocks"]["attn"]["wq"]
            print("WQ", list(wq))
            # every spec dim must divide
            def check(path, sds, spec):
                for ax, dim in zip(list(spec), sds.shape):
                    if ax is None: continue
                    n = mesh.shape[ax] if isinstance(ax, str) else 0
                    assert dim % n == 0, (path, sds.shape, spec)
            jax.tree.map(check,
                jax.tree_util.tree_map_with_path(lambda p, x: str(p), shapes),
                shapes, specs,
                is_leaf=lambda x: isinstance(x, P))
            print("SANITIZE", list(sanitize_spec(P("model"), (6,), mesh)))
            print("OK")
        """)
        assert "OK" in stdout
        assert "WQ [None, 'data', 'model']" in stdout
        assert "SANITIZE [None]" in stdout  # 6 % 4 != 0 -> dropped

    def test_moe_expert_parallel_rule(self):
        stdout = _run_sub("""
            import jax
            from repro.distributed.sharding import param_pspecs
            from repro.models.registry import get_model
            from repro.configs import get_config
            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = get_config("olmoe-1b-7b", smoke=True)
            model = get_model(cfg)
            shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                                    jax.random.key(0))
            specs = param_pspecs(shapes, mesh, fsdp=False)
            wg = specs["blocks"]["moe"]["experts"]["w_gate"]
            print("EXPERTS", list(wg))
        """)
        # (L, E, d, f): experts axis -> model (EP)
        assert "EXPERTS [None, 'model', None, None]" in stdout


class TestDistributedTrainStep:
    def test_tp_dp_train_step_runs_and_matches_single_device(self):
        stdout = _run_sub("""
            import jax, numpy as np
            import jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed.sharding import (param_shardings,
                                                    set_mesh_rules)
            from repro.models.registry import get_model
            from repro.configs import get_config
            from repro.data.pipeline import smoke_batch

            cfg, batch = smoke_batch("qwen2-7b", "train_4k")
            model = get_model(cfg)
            params = model.init(jax.random.key(0), cfg)
            loss_single, _ = model.loss(params, batch, cfg)

            mesh = jax.make_mesh((2, 2), ("data", "model"))
            set_mesh_rules(mesh, fsdp=False)
            p_sh = param_shardings(params, mesh)
            params_d = jax.device_put(params, p_sh)
            b_sh = {k: NamedSharding(mesh, P("data"))
                    for k in batch}
            batch_d = jax.device_put(batch, b_sh)
            with mesh:
                loss_dist, _ = jax.jit(
                    lambda p, b: model.loss(p, b, cfg))(params_d, batch_d)
            print("SINGLE", float(loss_single), "DIST", float(loss_dist))
            assert abs(float(loss_single) - float(loss_dist)) < 1e-3
            print("OK")
        """, devices=4)
        assert "OK" in stdout


class TestDryrunArtifacts:
    """Integration check over the committed dry-run results."""

    ART = os.path.join(REPO, "artifacts", "dryrun")

    def _cells(self, mesh):
        """Baseline cells only ("__variant" files are §Perf experiments,
        including deliberately-refuted configurations)."""
        d = os.path.join(self.ART, mesh)
        if not os.path.isdir(d):
            pytest.skip("dry-run artifacts not generated yet")
        return [json.load(open(os.path.join(d, f)))
                for f in os.listdir(d)
                if f.endswith(".json") and "__" not in f]

    def test_single_pod_all_cells_present(self):
        cells = self._cells("pod16x16")
        assert len(cells) == 32  # 10 archs x 3 shapes + 2 long_500k

    def test_multi_pod_all_cells_present(self):
        cells = self._cells("pod2x16x16")
        assert len(cells) == 32
        assert all(c["n_chips"] == 512 for c in cells)

    def test_memory_fits_hbm(self):
        for c in self._cells("pod16x16"):
            args_gib = c["memory_analysis"]["argument_size_in_bytes"] / 2**30
            assert args_gib < 16.0, (c["arch"], c["shape"], args_gib)

    def test_flops_physical(self):
        """Corrected HLO flops >= ~MODEL_FLOPS and bounded above.

        Train/prefill: within [0.8x, 20x] of 6ND/2ND (the >1 slack is real:
        remat recompute, MoE capacity padding + the baseline SPMD dispatch
        replication quantified in EXPERIMENTS §Perf). Decode cells: 2N·B
        ignores cache-length-dependent attention/MLA-decompress FLOPs, so
        only positivity + a loose ceiling is asserted."""
        for c in self._cells("pod16x16"):
            total = c["flops_per_chip"] * c["n_chips"]
            assert total > 0, (c["arch"], c["shape"])
            ratio = total / c["model_flops"]
            if c["step"] in ("train", "prefill"):
                assert 0.8 < ratio < 20, (c["arch"], c["shape"], ratio)
            else:
                assert ratio < 5000, (c["arch"], c["shape"], ratio)

    def test_train_cells_have_collectives(self):
        for c in self._cells("pod16x16"):
            if c["step"] == "train":
                assert c["collective_wire_bytes_per_chip"] > 0, (
                    c["arch"], "train step must all-reduce gradients")
