"""Pallas tiled GEMM vs pure-jnp oracle: shape/dtype sweeps + properties.

All kernel executions use interpret=True (CPU container; TPU is the target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based kernel tests need the 'test' extra "
           "(pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.ref import matmul_ref
from repro.kernels.tiled_matmul import BlockConfig, tiled_matmul

jax.config.update("jax_enable_x64", False)

SMALL = BlockConfig(block_m=16, block_n=128, block_k=128)


def _rand(shape, dtype, seed):
    x = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,n,k",
    [
        (16, 128, 128),     # single block
        (32, 256, 256),     # multi-block even
        (40, 200, 300),     # ragged in every dim (padding path)
        (1, 128, 512),      # degenerate row (decode-style GEMV)
        (128, 1, 64),       # degenerate col
        (17, 129, 257),     # all-prime-ish
    ],
)
def test_matches_oracle_shapes(m, n, k, dtype):
    a, b = _rand((m, k), dtype, 0), _rand((k, n), dtype, 1)
    got = tiled_matmul(a, b, config=SMALL, interpret=True)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("ta,tb", [(False, False), (False, True),
                                   (True, False), (True, True)])
def test_layouts(ta, tb):
    m, n, k = 48, 160, 96
    a = _rand((k, m) if ta else (m, k), jnp.float32, 2)
    b = _rand((n, k) if tb else (k, n), jnp.float32, 3)
    got = tiled_matmul(a, b, config=SMALL, transpose_a=ta, transpose_b=tb,
                       interpret=True)
    want = matmul_ref(a, b, transpose_a=ta, transpose_b=tb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("alpha,beta", [(1.0, 0.0), (2.0, 0.0), (0.5, 0.5),
                                        (1.0, 1.0)])
def test_alpha_beta(alpha, beta):
    m, n, k = 32, 128, 64
    a, b = _rand((m, k), jnp.float32, 4), _rand((k, n), jnp.float32, 5)
    c = _rand((m, n), jnp.float32, 6)
    got = tiled_matmul(a, b, c, config=SMALL, alpha=alpha, beta=beta,
                       interpret=True)
    want = matmul_ref(a, b, c, alpha=alpha, beta=beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bf16_in_f32_out():
    a, b = _rand((32, 64), jnp.bfloat16, 7), _rand((64, 128), jnp.bfloat16, 8)
    got = tiled_matmul(a, b, config=SMALL, out_dtype=jnp.float32, interpret=True)
    assert got.dtype == jnp.float32
    want = matmul_ref(a, b, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_fp32_accumulation_not_bf16():
    """K large enough that bf16 accumulation would visibly drift."""
    k = 4096
    a = jnp.full((8, k), 0.01, jnp.bfloat16)
    b = jnp.full((k, 128), 0.01, jnp.bfloat16)
    got = tiled_matmul(a, b, config=BlockConfig(8, 128, 512),
                       out_dtype=jnp.float32, interpret=True)
    # matching bf16 inputs: each product is (0.01 rounded to bf16)^2
    x = np.float32(np.asarray(jnp.bfloat16(0.01), np.float32))
    np.testing.assert_allclose(np.asarray(got), np.full((8, 128), k * x * x),
                               rtol=1e-3)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 160),
    k=st.integers(1, 200),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([128]),
    bk=st.sampled_from([128]),
)
def test_property_any_shape_any_block(m, n, k, bm, bn, bk):
    a = _rand((m, k), jnp.float32, m * 7 + n)
    b = _rand((k, n), jnp.float32, k * 3 + 1)
    got = tiled_matmul(a, b, config=BlockConfig(bm, bn, bk), interpret=True)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_config_invariance():
    """Different valid block configs give identical math."""
    a, b = _rand((64, 256), jnp.float32, 9), _rand((256, 256), jnp.float32, 10)
    outs = [
        np.asarray(tiled_matmul(a, b, config=BlockConfig(bm, bn, bk),
                                interpret=True))
        for bm, bn, bk in [(16, 128, 128), (32, 256, 256), (64, 128, 256)]
    ]
    for o in outs[1:]:
        # different bk => different fp32 summation order: allow ulp drift
        np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)


class TestOpsDispatch:
    def test_matmul_batched_lead_dims(self):
        from repro.kernels import ops

        ops.force_mode("xla")
        try:
            x = _rand((2, 3, 64), jnp.float32, 11)
            w = _rand((64, 32), jnp.float32, 12)
            y = ops.matmul(x, w)
            assert y.shape == (2, 3, 32)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(x) @ np.asarray(w), rtol=1e-5,
                atol=1e-5)
        finally:
            ops.force_mode("auto")

    def test_linear_bias(self):
        from repro.kernels import ops

        x = _rand((4, 16), jnp.float32, 13)
        w = _rand((16, 8), jnp.float32, 14)
        b = _rand((8,), jnp.float32, 15)
        y = ops.linear(x, w, b)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x) @ np.asarray(w) + np.asarray(b),
            rtol=1e-5, atol=1e-5)

    def test_pallas_interpret_mode_routes_kernel(self):
        from repro.kernels import ops

        ops.force_mode("pallas_interpret")
        try:
            x = _rand((8, 64), jnp.float32, 16)
            w = _rand((64, 128), jnp.float32, 17)
            y = ops.matmul(x, w, config=SMALL)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(x) @ np.asarray(w), rtol=1e-4,
                atol=1e-4)
        finally:
            ops.force_mode("auto")

    def test_gemm_xla_path_matches_ref(self):
        from repro.kernels import ops

        a, b = _rand((16, 32), jnp.float32, 18), _rand((32, 8), jnp.float32, 19)
        c = _rand((16, 8), jnp.float32, 20)
        y = ops.gemm(a, b, c, alpha=0.5, beta=0.5)
        want = matmul_ref(a, b, c, alpha=0.5, beta=0.5)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-5)
