"""Multi-device (tensor-parallel) serving tests.

The sharding contract is *bit parity*: a ServingEngine built with
``tp > 1`` — gather-mode explicit collectives, head/expert-sharded
params and caches, compute-overlapped row-parallel all-gathers — must
produce greedy token streams identical to the tp=1 engine for every
served family, while reporting collective wire/overlap telemetry and a
fleet (n_chips x) energy estimate.

These run in a subprocess with virtual host devices
(``--xla_force_host_platform_device_count``) so the main test process
keeps its single-device view (same idiom as test_sharding.py).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = textwrap.dedent(_PRELUDE) + textwrap.dedent(code)
    out = subprocess.run([sys.executable, "-c", src],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# one engine-build helper shared by every subprocess snippet
_PRELUDE = """
    import jax
    import numpy as np
    from repro.models.config import ModelConfig
    from repro.models.registry import get_model
    from repro.serving.engine import Request, ServingEngine

    BASE = dict(name="tp-test", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, vocab=128, param_dtype="float32",
                activation_dtype="float32", remat=False)
    FAMILY_KW = {
        "dense": dict(d_ff=128),
        "moe": dict(d_ff=0, n_experts=4, top_k=2, d_ff_expert=64,
                    capacity_factor=16.0),
        "mla_moe": dict(d_ff=128, n_experts=4, top_k=2, d_ff_expert=64,
                        capacity_factor=16.0, n_shared_experts=1,
                        kv_lora_rank=16, rope_head_dim=8),
        "mamba1": dict(d_ff=0, ssm_state=8, expand=2, d_conv=4),
        "mamba2": dict(d_ff=0, ssm_state=8, expand=2, d_conv=4,
                       ssm_headdim=16, ssm_ngroups=1),
        "hybrid": dict(d_ff=128, ssm_state=8, expand=2, d_conv=4,
                       ssm_headdim=16, ssm_ngroups=1, attn_every=2),
        "encdec": dict(d_ff=128, n_encoder_layers=2, gated_mlp=False),
        "vlm": dict(d_ff=128, qkv_bias=True, mrope=True,
                    mrope_sections=(4, 2, 2)),
    }
    LM_FAMILIES = ("dense", "hybrid", "mamba1", "mamba2", "mla_moe", "moe")

    def build(kind, **over):
        kw = dict(BASE, kind=kind, **FAMILY_KW[kind])
        kw.update(over)
        cfg = ModelConfig(**kw)
        model = get_model(cfg)
        params = model.init(jax.random.key(0), cfg)
        return cfg, model, params

    def extras(cfg, uid):
        rng = np.random.default_rng(900 + uid)
        if cfg.kind == "encdec":
            t = 5 + 2 * (uid % 3)
            return {"src_embeds": rng.standard_normal(
                (t, cfg.d_model)).astype(np.float32)}
        if cfg.kind == "vlm":
            grid = [(4, 4), (2, 3), None][uid % 3]
            if grid is None:
                return None
            gh, gw = grid
            return {"patch_embeds": rng.standard_normal(
                (gh * gw, cfg.d_model)).astype(np.float32),
                "grid_hw": grid}
        return None

    def reqs(cfg, specs):
        out = []
        for uid, (seed, n, mnt) in enumerate(specs):
            p = np.random.default_rng(seed).integers(
                0, cfg.vocab, n).astype(np.int32)
            out.append(Request(uid=uid, prompt=p, max_new_tokens=mnt,
                               extras=extras(cfg, uid)))
        return out

    SPECS = [(0, 11, 10), (1, 7, 8), (2, 19, 6), (3, 5, 12), (4, 13, 4)]

    def serve(cfg, model, params, tp, **kw):
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64,
                            tp=tp, **kw)
        for r in reqs(cfg, SPECS):
            eng.submit(r)
        res = {r.uid: r.tokens.tolist() for r in eng.run_until_empty()}
        return eng, res, eng.report()
"""


class TestTpBitParity:
    def test_all_families_tp2_streams_identical(self):
        """Every continuously-served family: tp=2 greedy streams ==
        tp=1, with nonzero wire time and overlap telemetry at tp=2."""
        stdout = _run_sub("""
            for kind in LM_FAMILIES:
                _, r1, _ = serve(*build(kind), tp=1)
                _, r2, rep = serve(*build(kind), tp=2)
                assert r1 == r2, (kind, r1, r2)
                assert rep["tp"] == 2
                assert rep["collective_wire_s"] > 0.0, kind
                assert 0.0 < rep["overlap_factor"] < 1.0, kind
                assert rep["model_s"] > 0.0, kind
                print("PARITY", kind)
            print("OK")
        """)
        assert "OK" in stdout
        for kind in ("dense", "moe", "mla_moe", "mamba1", "mamba2",
                     "hybrid"):
            assert f"PARITY {kind}" in stdout

    def test_admit_families_tp2_streams_identical(self):
        """encdec and vlm under tp=2: the admission pass (encoder +
        cross-KV projection, patch prefix) runs through the gather-mode
        sharded params, and greedy streams stay bit-identical to tp=1.
        Cross-KV and patch admission use tp_column/tp_row, so the tp=2
        contraction order matches tp=1 exactly."""
        stdout = _run_sub("""
            for kind in ("encdec", "vlm"):
                _, r1, _ = serve(*build(kind), tp=1)
                _, r2, rep = serve(*build(kind), tp=2)
                assert r1 == r2, (kind, r1, r2)
                assert rep["collective_wire_s"] > 0.0, kind
                print("PARITY", kind)
            print("OK")
        """)
        assert "OK" in stdout
        for kind in ("encdec", "vlm"):
            assert f"PARITY {kind}" in stdout

    def test_dense_tp4_streams_identical(self):
        """tp=4 over a 4-way-divisible head layout: parity plus sharded
        param/cache placement (params column-sharded on the mesh)."""
        stdout = _run_sub("""
            over = dict(n_heads=8, n_kv_heads=4)
            _, r1, _ = serve(*build("dense", **over), tp=1)
            eng, r4, rep = serve(*build("dense", **over), tp=4)
            assert r1 == r4
            assert rep["tp"] == 4
            spec = eng.params["blocks"]["attn"]["wq"].sharding.spec
            assert "model" in [ax for ax in spec if ax is not None]
            print("OK")
        """)
        assert "OK" in stdout

    def test_fleet_energy_scales_chips(self):
        """The tp report prices the fleet: per-step estimates carry
        n_chips=tp and J/token strictly above the single-chip run (same
        tokens, tp chips burning a shorter step)."""
        stdout = _run_sub("""
            _, _, rep1 = serve(*build("dense"), tp=1)
            eng, _, rep2 = serve(*build("dense"), tp=2)
            assert rep2["j_per_token"] > rep1["j_per_token"]
            est = eng._step_energy(("decode", eng.max_batch),
                                   eng.max_batch,
                                   batch_rows=eng.max_batch)
            assert est.n_chips == 2
            assert est.collective_s > 0.0
            print("OK")
        """)
        assert "OK" in stdout


class TestTpPagedKv:
    def test_sharded_pool_parity_and_refcount_hygiene(self):
        """Paged KV under tp=2: the shared pool's k/v pages shard on the
        head axis, streams match the tp=1 dense layout, and after two
        full drains every non-registry page ref has been released (no
        leak from the sharded pool threading)."""
        stdout = _run_sub("""
            kw = dict(admission="chunked", chunk_tokens=16,
                      kv_layout="paged", page_size=16)
            _, r_dense, _ = serve(*build("dense"), tp=1)
            eng, r_paged, rep = serve(*build("dense"), tp=2, **kw)
            assert r_dense == r_paged
            spec = eng._pool["k_pages"].sharding.spec
            assert "model" in [ax for ax in spec if ax is not None]
            alloc = eng._allocator
            use1 = alloc.in_use
            held1 = int((alloc.refs > 0).sum())
            # second drain over the same prompts: prefix registry may
            # hold pages, but repeated serving must not accumulate refs
            for r in reqs(eng.cfg, SPECS):
                r.uid += 100
                eng.submit(r)
            r_again = {r.uid - 100: r.tokens.tolist()
                       for r in eng.run_until_empty()}
            assert r_again == r_paged
            assert alloc.in_use == use1
            assert int((alloc.refs > 0).sum()) == held1
            assert (alloc.refs >= 0).all()
            print("OK")
        """)
        assert "OK" in stdout


class TestGrainSharded:
    def test_wider_grain_keeps_tp_parity(self):
        """ssm_serve_grain=32 composes with tp=2: chunked mamba2 streams
        still match the tp=1 engine at the same grain."""
        stdout = _run_sub("""
            kw = dict(admission="chunked", chunk_tokens=32,
                      ssm_serve_grain=32)
            _, r1, _ = serve(*build("mamba2"), tp=1, **kw)
            _, r2, _ = serve(*build("mamba2"), tp=2, **kw)
            assert r1 == r2
            print("OK")
        """)
        assert "OK" in stdout
