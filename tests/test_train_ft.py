"""Fault-tolerance primitives: `train.ft` straggler detection edges and
the SIGTERM preemption -> final-save path.

The serving fleet reuses `StragglerDetector` over per-member step-time
ratios (`serving/scheduler.py`), so its boundary behavior — patience
reset on recovery, the strict threshold inequality, the two-host median,
the single-host no-peer gate — is load-bearing for eviction decisions,
not just training telemetry. Signal-delivery tests run in a subprocess
so a real SIGTERM exercises the installed handler without killing the
test runner.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from repro.train.ft import StragglerConfig, StragglerDetector


def _detector(n_hosts: int, *, threshold: float = 1.5,
              patience: int = 3) -> StragglerDetector:
    return StragglerDetector(
        n_hosts, StragglerConfig(threshold=threshold, patience=patience))


def _prime(det: StragglerDetector, ewmas: dict[int, float]) -> None:
    """Set each host's EWMA exactly (the first record seeds it)."""
    for host, v in ewmas.items():
        det.record(host, v)


# ---------------------------------------------------------------------------
# EWMA / flagging edges
# ---------------------------------------------------------------------------


def test_patience_resets_on_recovery():
    """A host that dips back under the threshold before `patience`
    consecutive slow steps restarts its streak from zero."""
    det = _detector(3, patience=3)
    _prime(det, {0: 1.0, 1: 1.0, 2: 10.0})
    assert det.update_flags() == []
    assert det.update_flags() == []          # streak at 2, not flagged
    # recovery: hammer fast steps until the EWMA is back under 1.5x med
    while det._ewma[2] > 1.5:
        det.record(2, 1.0)
    assert det.update_flags() == []          # streak reset
    # slow again: the old streak must not carry over
    det.record(2, 100.0)
    assert det.update_flags() == []
    assert det.update_flags() == []
    assert det.update_flags() == [2]         # fresh 3-streak completes


def test_threshold_boundary_is_strict():
    """Exactly threshold x median is healthy; only strictly above
    counts toward the streak."""
    det = _detector(3, threshold=1.5, patience=1)
    _prime(det, {0: 1.0, 1: 1.0, 2: 1.5})    # med = 1.0, bound = 1.5
    assert det.update_flags() == []
    det2 = _detector(3, threshold=1.5, patience=1)
    _prime(det2, {0: 1.0, 1: 1.0, 2: 1.5 + 1e-9})
    assert det2.update_flags() == [2]


def test_single_host_fleet_never_flags():
    """One host has no peer to be slower than — the known-count gate
    (max(2, n//2)) keeps update_flags empty no matter the history."""
    det = _detector(1, patience=1)
    for _ in range(10):
        det.record(0, 1000.0)
        assert det.update_flags() == []


def test_two_host_straggler_is_detectable():
    """Two-host median regression: with the upper-median element the
    slower host *was* the median, so it could never exceed 1.5x itself
    and a 2-host fleet was blind to its straggler. The true median
    (central pair averaged) makes it reachable: e > 1.5*(b+e)/2 iff
    e > 3b."""
    det = _detector(2, threshold=1.5, patience=2)
    _prime(det, {0: 1.0, 1: 4.0})            # med = 2.5, bound = 3.75
    assert det.update_flags() == []          # streak 1
    assert det.update_flags() == [1]         # patience met


def test_two_host_below_triple_stays_healthy():
    """The flip side of the 2-host bound: e <= 3b never flags."""
    det = _detector(2, threshold=1.5, patience=1)
    _prime(det, {0: 1.0, 1: 3.0})            # med = 2.0, bound = 3.0
    for _ in range(5):
        assert det.update_flags() == []


def test_reset_clears_history():
    """`reset(host)` forgets the EWMA and streak — an evicted member
    rejoining the fleet must not be re-flagged on stale history."""
    det = _detector(2, patience=1)
    _prime(det, {0: 1.0, 1: 100.0})
    assert det.update_flags() == [1]
    det.reset(1)
    assert det._ewma[1] is None
    assert det.update_flags() == []          # no peer pair -> gate holds
    _prime(det, {1: 1.0})                    # healthy rejoin
    assert det.update_flags() == []


def test_unknown_hosts_gate():
    """update_flags stays empty until at least max(2, n//2) hosts have
    reported — a half-silent fleet has no trustworthy median."""
    det = _detector(8, patience=1)
    for h in range(3):
        det.record(h, 1.0)
        assert det.update_flags() == []      # 1..3 known < 4
    det.record(3, 100.0)
    assert det.update_flags() == [3]         # 4 known: gate opens


# ---------------------------------------------------------------------------
# preemption: real signal delivery, in a subprocess
# ---------------------------------------------------------------------------

_ENV = {**os.environ,
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
        "JAX_PLATFORMS": "cpu"}


def _run_py(script: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                          capture_output=True, text=True, timeout=120,
                          env=_ENV)


def test_sigterm_sets_preempted_flag():
    """A real SIGTERM delivered to the process flips the handler's flag
    instead of killing it, and restore() reinstates the default
    disposition."""
    proc = _run_py("""
        import os, signal
        from repro.train.ft import PreemptionHandler

        h = PreemptionHandler()
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.preempted, "flag not set by SIGTERM"
        h.restore()
        assert signal.getsignal(signal.SIGTERM) is not h._handler
        print("HANDLED")
    """)
    assert proc.returncode == 0, proc.stderr
    assert "HANDLED" in proc.stdout


def test_sigterm_triggers_final_blocking_save():
    """SIGTERM mid-run makes `run_train_loop` cut the run short with one
    final *blocking* checkpoint save (the preemption contract: the state
    on disk is the state the summary reports)."""
    proc = _run_py("""
        import os, signal
        import numpy as np
        from repro.train.loop import LoopConfig, run_train_loop

        class Loader:
            def next(self):
                return {"x": np.zeros(1)}
            def checkpoint(self):
                return {"pos": 0}

        class Ckpt:
            saves = []
            def save(self, step, state, data_state=None, blocking=False):
                self.saves.append((step, bool(blocking)))
            def wait(self):
                pass

        def train_step(state, batch):
            state["n"] += 1
            if state["n"] == 3:
                os.kill(os.getpid(), signal.SIGTERM)
            return state, {"loss": np.float32(0.5)}

        ckpt = Ckpt()
        state, summary = run_train_loop(
            train_step=train_step, state={"n": 0}, loader=Loader(),
            ckpt=ckpt, loop_cfg=LoopConfig(total_steps=100,
                                           ckpt_every=1000),
            log_fn=lambda msg: None)
        assert summary["preempted"], summary
        assert summary["final_step"] == 3, summary
        assert ckpt.saves == [(3, True)], ckpt.saves
        print("SAVED", ckpt.saves)
    """)
    assert proc.returncode == 0, proc.stderr
    assert "SAVED [(3, True)]" in proc.stdout
