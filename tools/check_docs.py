"""Docs-freshness check: every module or file referenced from docs/*.md
must still exist in the tree.

Scans the docs for two kinds of references:

  * dotted module paths (``repro.serving.paging``, optionally with
    trailing attribute parts like ``repro.kernels.ops.matmul``) — resolved
    against ``src/`` by walking components: directories descend, a ``.py``
    file ends the module part, and anything after a found module is an
    attribute (not checkable without importing, deliberately skipped so
    this runs with zero dependencies in the lint job);
  * repo-relative file paths with known roots (``tests/test_paged_kv.py``,
    ``benchmarks/bench_serving.py``, ...).

A reference whose walk dies *at the filesystem level* — a deleted or
renamed module/file — fails the build with the doc and line that points at
it. Run: ``python tools/check_docs.py`` from anywhere inside the repo.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"
SRC = REPO / "src"

MODULE_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
PATH_RE = re.compile(
    r"\b(?:src|tests|benchmarks|tools|examples|docs)/[\w./-]+\.\w+")

# dotted names that look like modules but aren't (artifact format tags,
# example identifiers) — extend when a doc legitimately needs one
NOT_MODULES = {
    "repro.perf_predictor",
}


def module_exists(dotted: str) -> bool:
    """True if the leading components of ``dotted`` resolve to a package
    directory or module file under src/ (trailing attribute parts are
    accepted once a module file is found)."""
    path = SRC
    for comp in dotted.split("."):
        if (path / comp).is_dir():
            path = path / comp
            continue
        if (path / f"{comp}.py").is_file():
            return True                      # rest are attributes
        # the walk died inside a directory: a real module would have to
        # live here. Attributes of a package __init__ are rare enough
        # that docs should reference the defining module instead.
        return False
    return True                              # dotted name ends on a package


def check_file(md: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for ref in MODULE_RE.findall(line):
            if ref in NOT_MODULES:
                continue
            if not module_exists(ref):
                errors.append(f"{md.relative_to(REPO)}:{lineno}: "
                              f"module reference `{ref}` does not resolve "
                              f"under src/")
        for ref in PATH_RE.findall(line):
            if not (REPO / ref).exists():
                errors.append(f"{md.relative_to(REPO)}:{lineno}: "
                              f"path reference `{ref}` does not exist")
    return errors


def main() -> int:
    docs = sorted(DOCS.glob("*.md"))
    if not docs:
        print("check_docs: no docs/*.md found", file=sys.stderr)
        return 1
    errors = []
    n_refs = 0
    for md in docs:
        text = md.read_text()
        n_refs += len(MODULE_RE.findall(text)) + len(PATH_RE.findall(text))
        errors.extend(check_file(md))
    if errors:
        print(f"check_docs: {len(errors)} stale reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs: {len(docs)} docs, {n_refs} references, all fresh")
    return 0


if __name__ == "__main__":
    sys.exit(main())
